//! Table 1: FSDP2 interleaved copy overhead for GPT-OSS-120B on 64 H800s.
//! Reports interleaved Copy-Out vs AllGather (AG path) and interleaved
//! Copy-In vs ReduceScatter (RS path), for Shard(0) and Shard(1).
//!
//! Paper values: AG 43.71 ms / Copy-Out 5.22 ms (Shard0), 13.72 ms
//! (Shard1); RS 94.24 ms / Copy-In 12.37 ms (Shard0), 23.14 ms (Shard1).

use vescale_fsdp::comm::{CopyKind, Fabric};
use vescale_fsdp::config::presets;
use vescale_fsdp::util::table::Table;

fn main() {
    let fabric = Fabric::h800();
    let preset = presets::gptoss120b();
    let m = 64usize;

    // the communication bucket the paper measures: per-layer parameter
    // group of GPT-OSS-120B in bf16
    let layer = &preset.groups[1];
    let bucket_bytes = layer.numel() * 2;
    let per_rank = bucket_bytes / m as u64;

    let mut t = Table::new(
        "Table 1 — interleaved copy overhead, GPT-OSS-120B, 64 H800",
        &["format", "AllGather", "Copy-Out", "ReduceScatter", "Copy-In"],
    );
    for (label, kind) in [
        ("Shard(0)", CopyKind::InterleavedRows),
        ("Shard(1)", CopyKind::InterleavedCols),
    ] {
        // FSDP1/FSDP2 do not enforce NCCL alignment; Table-1 collectives
        // were measured on aligned bulk buffers, so model aligned here and
        // account misalignment in the end-to-end Fig-8 runs.
        let ag = fabric.all_gather_time(m, per_rank, true);
        let rs = fabric.reduce_scatter_time(m, per_rank, true);
        let copy_out = fabric.copy_time(bucket_bytes, kind);
        // Copy-In stages fp32 gradients into the bf16 comm buffer: 2x the
        // read volume plus the cast, hence the paper's larger numbers
        let copy_in = fabric.copy_time(bucket_bytes * 2, kind);
        t.rowv(vec![
            label.into(),
            format!("{:.2} ms", ag * 1e3),
            format!("{:.2} ms", copy_out * 1e3),
            format!("{:.2} ms", rs * 1e3),
            format!("{:.2} ms", copy_in * 1e3),
        ]);
    }
    t.print();
    println!("paper:    Shard(0): 43.71 / 5.22 / 94.24 / 12.37 ms");
    println!("          Shard(1): 44.35 / 13.72 / 95.36 / 23.14 ms");
    println!("bucket: layer group = {:.2} GB bf16 ({} params)",
             bucket_bytes as f64 / 1e9, layer.params.len());
    println!("veScale-FSDP (DBuffer zero-copy): Copy-Out = Copy-In = 0 ms");
}
