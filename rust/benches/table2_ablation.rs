//! Table 2: component ablation on a GPT-OSS-style model with 8-bit Adam
//! on 32 devices — normalized throughput after disabling each component.
//!
//! Paper: Combined 100% | no DBuffer 92.8% | no Planner 65.4% |
//! no RaggedShard N/A (not meaningfully runnable).

use vescale_fsdp::baselines;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec, StepReport};
use vescale_fsdp::planner::{naive_concat_shard, split_blocks, TensorDecl};
use vescale_fsdp::util::table::Table;

fn main() {
    let fabric = Fabric::h800();
    let gpu = GpuSpec::h800();
    let preset = presets::gptoss120b();
    let m = 32usize;
    let parallel = ParallelConfig::fsdp_only(m);
    let tokens = 8192u64;
    // 32-row quant blocks (the 8-bit Adam granularity)
    let gran = 32u64 * 2880;

    let run = |sys| -> StepReport {
        simulate_step(&preset, &parallel, OptimKind::Adam8bit, tokens, &fabric, &gpu, &sys)
            .unwrap()
    };
    let full = run(baselines::vescale(gran));
    let no_db = run(baselines::vescale_no_dbuffer(gran));
    let mut no_plan = run(baselines::vescale_no_planner(gran));

    // Without the planner, quant blocks straddle shard boundaries; the
    // system falls back to DTensor redistribution to reassemble optimizer
    // state before each per-block quantization (paper §6.5) — cost the
    // extra collective per straddled block region.
    let decls: Vec<TensorDecl> = preset
        .all_params()
        .iter()
        .map(|p| TensorDecl::new(&p.name, p.numel(), gran.min(p.numel()).max(1)))
        .collect();
    let naive = naive_concat_shard(&decls, m, 1);
    let straddled = split_blocks(&naive);
    // each straddled block forces a boundary-region exchange: one gather +
    // one scatter of the block across 2 ranks
    let extra_bytes = straddled * gran * 4 * 2;
    let extra = fabric.all_gather_time(m, extra_bytes / m as u64, false)
        + fabric.reduce_scatter_time(m, extra_bytes / m as u64, false);
    no_plan.step_time += extra;
    no_plan.tokens_per_sec = tokens as f64 * m as f64 / no_plan.step_time;

    let mut t = Table::new(
        "Table 2 — component ablation (GPT-OSS-style, 8-bit Adam, 32 GPUs)",
        &["veScale-FSDP component", "normalized throughput", "paper"],
    );
    let pct = |r: &StepReport| format!("{:.1}%", r.tokens_per_sec / full.tokens_per_sec * 100.0);
    t.rowv(vec!["Combined".into(), "100.0%".into(), "100.0%".into()]);
    t.rowv(vec!["Disable DBuffer only".into(), pct(&no_db), "92.8%".into()]);
    t.rowv(vec!["Disable Planning Algorithm only".into(), pct(&no_plan), "65.4%".into()]);
    t.rowv(vec![
        "Disable RaggedShard only".into(),
        "N/A".into(),
        "N/A".into(),
    ]);
    t.print();
    println!("(straddled quant blocks without planning: {straddled};");
    println!(" RaggedShard disabled = block-wise 8-bit Adam not runnable without");
    println!(" intrusive model changes or hand-written collectives — N/A.)");
}
