//! Fig-12 (repo-specific): quantized-communication bench — **measured**
//! wire bytes (payload / scale / pad, straight from what the collectives
//! shipped) and wall-clock for F32 vs Bf16 vs Q8 across rank counts, a
//! fig-10-style convergence check (Q8-with-error-feedback final loss vs
//! f32), and the `fsdp::sim` comm-time prediction at the matching wire
//! precision next to the engine's fabric-model measurement.
//!
//!     cargo bench --bench fig12_quant_comm [-- --model tiny --steps 12
//!         --warmup 1 --block 64 --smoke]
//!
//! `--smoke` shrinks the sweep to one mesh and two steps (the CI mode).
//! Emits `BENCH_quant.json` at the crate root.

use vescale_fsdp::baselines;
use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;
use vescale_fsdp::util::table::Table;

struct RunOut {
    wall_per_step: f64,
    sim_comm_per_step: f64,
    wire_payload: u64,
    wire_scale: u64,
    wire_pad: u64,
    final_loss: f32,
}

fn run(
    model: &str,
    m: usize,
    prec: CommPrecision,
    warmup: usize,
    steps: usize,
) -> anyhow::Result<RunOut> {
    let mut t = TrainSession::builder(model)
        .devices(m)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .backend(CommBackend::Threaded)
        .exec(ExecMode::Pipelined { prefetch: 2 })
        .comm_precision(prec)
        .build()?;
    for _ in 0..warmup {
        t.train_step()?;
    }
    let log_before = t.log.len();
    let comm_before = t.engine.comm.sim_time();
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        t.train_step()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let sim_comm = t.engine.comm.sim_time() - comm_before;
    let (mut payload, mut scale, mut pad) = (0u64, 0u64, 0u64);
    for l in &t.log[log_before..] {
        payload += l.wire_payload;
        scale += l.wire_scale;
        pad += l.wire_pad;
    }
    let tail: Vec<f32> = t.log.iter().rev().take(5).map(|l| l.loss).collect();
    Ok(RunOut {
        wall_per_step: wall / steps as f64,
        sim_comm_per_step: sim_comm / steps as f64,
        wire_payload: payload,
        wire_scale: scale,
        wire_pad: pad,
        final_loss: tail.iter().sum::<f32>() / tail.len() as f32,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let model = args.str_or("model", "tiny");
    let block = args.usize_or("block", 64);
    let (meshes, steps, warmup) = if smoke {
        (vec![2usize], args.usize_or("steps", 2), 0)
    } else {
        (vec![2usize, 4, 8], args.usize_or("steps", 12), args.usize_or("warmup", 1))
    };
    let fabric = Fabric::by_name(&args.str_or("fabric", "h800"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fabric"))?;
    let precisions = [
        CommPrecision::F32,
        CommPrecision::Bf16,
        CommPrecision::Q8 { block },
    ];
    println!(
        "model {model}, meshes {meshes:?}, {steps} steps (+{warmup} warmup), fabric {}{}\n",
        fabric.name,
        if smoke { ", SMOKE" } else { "" }
    );

    let preset = presets::by_name(&model)
        .ok_or_else(|| anyhow::anyhow!("no sim preset for '{model}'"))?;
    let cfgs = vescale_fsdp::runtime::Manifest::builtin();
    let mcfg = cfgs
        .configs
        .get(&model)
        .ok_or_else(|| anyhow::anyhow!("no model config '{model}'"))?
        .clone();
    let tokens_per_dev = (mcfg.batch * mcfg.seq) as u64;

    let mut table = Table::new(
        "Fig 12 — quantized communication (measured wire bytes + wall, threaded pipelined)",
        &[
            "mesh",
            "wire",
            "s/step",
            "payload MB",
            "scale MB",
            "pad MB",
            "wire vs f32",
            "sim comm s/step",
            "sim predicted s",
            "final loss",
        ],
    );
    let mut rows = Vec::new();
    let mut q8_reduction_min = f64::INFINITY;
    let mut q8_loss_ok = true;
    for &m in &meshes {
        let mut f32_total = 0u64;
        let mut f32_loss = 0.0f32;
        for prec in precisions {
            let r = run(&model, m, prec, warmup, steps)?;
            let total = r.wire_payload + r.wire_scale + r.wire_pad;
            let (reduction, red_str) = if prec.is_f32() {
                f32_total = total;
                f32_loss = r.final_loss;
                (1.0, "1.00x".to_string())
            } else {
                let red = f32_total as f64 / total.max(1) as f64;
                (red, format!("{red:.2}x"))
            };
            if let CommPrecision::Q8 { .. } = prec {
                q8_reduction_min = q8_reduction_min.min(reduction);
                let gap = (r.final_loss - f32_loss).abs() / f32_loss.max(1e-6);
                q8_loss_ok &= gap <= 0.05;
            }
            // sim.rs prediction of one step's comm seconds at this wire
            // precision (same vescale behavior the overlap bench uses)
            let sim = simulate_step(
                &preset,
                &ParallelConfig::fsdp_only(m),
                OptimKind::AdamW,
                tokens_per_dev,
                &fabric,
                &GpuSpec::h800(),
                &baselines::vescale_with_precision(1, prec),
            )?;
            table.rowv(vec![
                format!("{m}"),
                prec.name(),
                format!("{:.4}", r.wall_per_step),
                format!("{:.3}", r.wire_payload as f64 / 1e6),
                format!("{:.3}", r.wire_scale as f64 / 1e6),
                format!("{:.3}", r.wire_pad as f64 / 1e6),
                red_str,
                format!("{:.5}", r.sim_comm_per_step),
                format!("{:.5}", sim.comm_time),
                format!("{:.4}", r.final_loss),
            ]);
            rows.push(Json::obj(vec![
                ("mesh", Json::num(m as f64)),
                ("precision", Json::str(&prec.name())),
                ("s_per_step", Json::num(r.wall_per_step)),
                ("wire_payload_bytes", Json::num(r.wire_payload as f64)),
                ("wire_scale_bytes", Json::num(r.wire_scale as f64)),
                ("wire_pad_bytes", Json::num(r.wire_pad as f64)),
                ("wire_total_bytes", Json::num(total as f64)),
                ("wire_reduction_vs_f32", Json::num(reduction)),
                ("sim_comm_s_per_step", Json::num(r.sim_comm_per_step)),
                ("sim_predicted_comm_s", Json::num(sim.comm_time)),
                ("final_loss", Json::num(r.final_loss as f64)),
            ]));
        }
    }
    table.print();
    println!(
        "\nQ8 wire reduction vs f32 (worst mesh): {q8_reduction_min:.2}x ({})",
        if q8_reduction_min >= 3.0 { ">= 3x target met" } else { "below 3x target" }
    );
    println!(
        "Q8 final loss within 5% of f32 on every mesh: {q8_loss_ok} (fig-10-style convergence)"
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig12_quant_comm")),
        ("model", Json::str(&model)),
        ("steps", Json::num(steps as f64)),
        ("smoke", Json::Bool(smoke)),
        ("fabric", Json::str(fabric.name)),
        ("q8_block", Json::num(block as f64)),
        ("rows", Json::Arr(rows)),
        ("q8_wire_reduction_min", Json::num(q8_reduction_min)),
        ("q8_wire_reduction_ge_3x", Json::Bool(q8_reduction_min >= 3.0)),
        ("q8_loss_within_5pct", Json::Bool(q8_loss_ok)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_quant.json");
    std::fs::write(path, out.to_string())?;
    println!("\nwrote {path}");
    Ok(())
}
