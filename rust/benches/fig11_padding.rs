//! Figure 11: padding overhead of RaggedShard communication vs FSDP size,
//! for DeepSeek-V3-671B (a) and GPT-OSS-120B (b), sweeping the expert-MLP
//! row granularity over {1, 16, 128} rows (128 = DeepSeek's 128x128 tiles).
//! Also reports Algorithm 1's planning wall-clock (§6.4: < 0.3 s).

use vescale_fsdp::config::presets;
use vescale_fsdp::planner::{plan, TensorDecl};
use vescale_fsdp::util::table::Table;

fn decls_for(group: &presets::ParamGroup, rows: u64) -> Vec<TensorDecl> {
    group
        .params
        .iter()
        .map(|p| {
            // DeepSeek-style scheme: quantize only FFN/expert weights
            let row = *p.shape.last().unwrap() as u64;
            let g = if p.name.contains("expert") || p.name.contains("mlp") {
                (rows * row).min(p.numel()).max(1)
            } else {
                1
            };
            TensorDecl::new(&p.name, p.numel(), g)
        })
        .collect()
}

/// Plan every communication bucket (FSDP wrap unit = one layer group, as
/// the system actually communicates) and aggregate padding — the per-
/// bucket LCM rounding is where the paper's step-fluctuations come from.
fn model_padding(preset: &presets::ModelPreset, m: usize, rows: u64) -> (f64, f64) {
    use std::collections::HashMap;
    let mut pad = 0u64;
    let mut real = 0u64;
    let mut plan_time = 0.0f64;
    // structurally-identical layers plan identically: plan each unique
    // bucket signature once (what a production planner does too)
    let mut cache: HashMap<(u64, usize), u64> = HashMap::new();
    for group in &preset.groups {
        let key = (group.numel(), group.params.len());
        let padding = match cache.get(&key) {
            Some(&p) => p,
            None => {
                let decls = decls_for(group, rows);
                let t0 = std::time::Instant::now();
                let layout = plan(&decls, m, 4).unwrap();
                plan_time += t0.elapsed().as_secs_f64();
                debug_assert!(layout.verify().is_ok());
                cache.insert(key, layout.padding());
                layout.padding()
            }
        };
        pad += padding;
        real += group.numel();
    }
    (pad as f64 / real as f64, plan_time)
}

fn main() {
    let sizes = [8usize, 16, 32, 64, 128, 256, 512];
    let mut worst_plan_time = 0.0f64;
    for preset in [presets::dsv3_671b(), presets::gptoss120b()] {
        let mut t = Table::new(
            &format!("Fig 11 — padding overhead, {}", preset.name),
            &["FSDP size", "1x rows", "16x rows", "128x rows"],
        );
        for m in sizes {
            let mut row = vec![format!("{m}")];
            for rows in [1u64, 16, 128] {
                let (ratio, pt) = model_padding(&preset, m, rows);
                worst_plan_time = worst_plan_time.max(pt);
                row.push(format!("{:.3}%", ratio * 100.0));
            }
            t.row(&row);
        }
        t.print();
    }
    println!("planner wall-clock worst case: {worst_plan_time:.3} s (paper: < 0.3 s)");
    println!("expected shape (paper): <3% at 1x/16x everywhere; 128x on GPT-OSS");
    println!("spikes (fused experts) while DeepSeek-V3 stays mostly <3%.");
}
