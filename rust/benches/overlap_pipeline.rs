//! Overlap-executor bench (repo-specific): the bucket-pipelined schedule
//! vs the sequential step loop on the L2 preset, measured on this host —
//! wall-clock per step, measured exposed-communication fraction (wall
//! seconds the step spent blocked on collectives), and the allocator's
//! measured peak reserved bytes — next to the `fsdp::sim` prediction of
//! the same preset's exposed-comm fraction on the modeled H800 fabric.
//! A bit-identity check confirms every mode ran the same trajectory.
//!
//! Each run is traced at the `comm` level, so the report also carries the
//! tracer's overlap efficiency (hidden / total transport seconds) and the
//! measured-vs-`fsdp::sim` seconds per collective op.
//!
//!     cargo bench --bench overlap_pipeline [-- --model tiny --mesh 4
//!                                             --steps 6 --warmup 1]
//!
//! Emits `BENCH_overlap.json` at the crate root.

use vescale_fsdp::baselines;
use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::trace::{TraceLevel, TraceSummary};
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;
use vescale_fsdp::util::table::Table;

struct RunStats {
    wall_per_step: f64,
    exposed_per_step: f64,
    peak_reserved: u64,
    losses: Vec<f32>,
    /// Tracer roll-up over the whole run (warmup included): overlap
    /// efficiency and measured-vs-sim per collective.
    summary: TraceSummary,
}

fn run(
    model: &str,
    m: usize,
    exec: ExecMode,
    fabric: &Fabric,
    warmup: usize,
    steps: usize,
) -> anyhow::Result<RunStats> {
    let mut t = TrainSession::builder(model)
        .devices(m)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .backend(CommBackend::Threaded)
        .exec(exec)
        .fabric(fabric.clone())
        .trace(TraceLevel::Comm)
        .build()?;
    let mut losses = Vec::with_capacity(warmup + steps);
    for _ in 0..warmup {
        losses.push(t.train_step()?);
    }
    let t0 = std::time::Instant::now();
    let exposed_before: f64 = t.log.iter().map(|l| l.exposed_s).sum();
    for _ in 0..steps {
        losses.push(t.train_step()?);
    }
    let wall = t0.elapsed().as_secs_f64();
    let exposed: f64 = t.log.iter().map(|l| l.exposed_s).sum::<f64>() - exposed_before;
    let (peak_reserved, _) = t.engine.memory_stats();
    let summary = t.trace_summary();
    Ok(RunStats {
        wall_per_step: wall / steps as f64,
        exposed_per_step: exposed / steps as f64,
        peak_reserved,
        losses,
        summary,
    })
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model = args.str_or("model", "tiny");
    let m = args.usize_or("mesh", 4);
    let steps = args.usize_or("steps", 6);
    let warmup = args.usize_or("warmup", 1);
    let fabric = Fabric::by_name(&args.str_or("fabric", "h800"))
        .ok_or_else(|| anyhow::anyhow!("unknown --fabric"))?;
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    println!(
        "model {model}, mesh {m}, fabric {}, host cores {cores}; {steps} steps (+{warmup} warmup)\n",
        fabric.name
    );

    // ---- sim.rs prediction for the same preset ----
    let preset = presets::by_name(&model)
        .ok_or_else(|| anyhow::anyhow!("no sim preset for '{model}'"))?;
    let cfgs = vescale_fsdp::runtime::Manifest::builtin();
    let mcfg = cfgs
        .configs
        .get(&model)
        .ok_or_else(|| anyhow::anyhow!("no model config '{model}'"))?
        .clone();
    let tokens_per_dev = (mcfg.batch * mcfg.seq) as u64;
    let sim = simulate_step(
        &preset,
        &ParallelConfig::fsdp_only(m),
        OptimKind::AdamW,
        tokens_per_dev,
        &fabric,
        &GpuSpec::h800(),
        &baselines::vescale(1),
    )?;
    let sim_exposed_frac = sim.exposed_comm / sim.step_time.max(1e-12);

    // ---- measured runs: sequential vs pipelined, threaded backend ----
    let modes = [
        ExecMode::Sequential,
        ExecMode::Pipelined { prefetch: 1 },
        ExecMode::Pipelined { prefetch: 2 },
    ];
    let mut table = Table::new(
        "Overlap executor — pipelined vs sequential (threaded backend, measured)",
        &[
            "schedule",
            "s/step",
            "exposed s",
            "exposed %",
            "overlap eff",
            "peak res MB",
            "bit-identical",
        ],
    );
    let mut rows = Vec::new();
    let mut stats: Vec<RunStats> = Vec::new();
    for mode in modes {
        stats.push(run(&model, m, mode, &fabric, warmup, steps)?);
    }
    let reference = &stats[0].losses;
    for (mode, st) in modes.iter().zip(&stats) {
        let identical = st
            .losses
            .iter()
            .zip(reference)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        let frac = st.exposed_per_step / st.wall_per_step.max(1e-12);
        table.rowv(vec![
            mode.name(),
            format!("{:.4}", st.wall_per_step),
            format!("{:.4}", st.exposed_per_step),
            format!("{:.1}%", frac * 100.0),
            format!("{:.1}%", st.summary.overlap_efficiency * 100.0),
            format!("{:.2}", st.peak_reserved as f64 / 1e6),
            format!("{identical}"),
        ]);
        rows.push(Json::obj(vec![
            ("schedule", Json::str(&mode.name())),
            ("prefetch", Json::num(mode.prefetch() as f64)),
            ("s_per_step", Json::num(st.wall_per_step)),
            ("exposed_s_per_step", Json::num(st.exposed_per_step)),
            ("exposed_frac", Json::num(frac)),
            ("overlap_efficiency", Json::num(st.summary.overlap_efficiency)),
            ("peak_reserved_bytes", Json::num(st.peak_reserved as f64)),
            ("bit_identical", Json::Bool(identical)),
            ("trace_summary", st.summary.to_json()),
        ]));
    }
    table.print();

    let best_pipelined = stats[1..]
        .iter()
        .map(|s| s.wall_per_step)
        .fold(f64::INFINITY, f64::min);
    let speedup = stats[0].wall_per_step / best_pipelined;
    let pipelined_wins = best_pipelined < stats[0].wall_per_step;
    println!(
        "\npipelined vs sequential wall-clock: {speedup:.2}x ({})",
        if pipelined_wins { "pipelined wins" } else { "sequential wins on this host" }
    );
    println!(
        "measured exposed-comm fraction (pipelined-1): {:.1}%  |  sim.rs prediction ({}, {} dev, {} model): {:.1}%",
        100.0 * stats[1].exposed_per_step / stats[1].wall_per_step.max(1e-12),
        preset.name,
        m,
        fabric.name,
        100.0 * sim_exposed_frac
    );
    println!(
        "measured peak reserved: seq {:.2} MB vs pipelined-1 {:.2} MB (prefetch bounds live buckets)",
        stats[0].peak_reserved as f64 / 1e6,
        stats[1].peak_reserved as f64 / 1e6
    );
    println!(
        "tracer overlap efficiency: seq {:.1}% vs pipelined-1 {:.1}% (hidden / total transport s)",
        100.0 * stats[0].summary.overlap_efficiency,
        100.0 * stats[1].summary.overlap_efficiency
    );
    println!("measured vs sim per collective (pipelined-1):");
    for op in &stats[1].summary.per_op {
        println!(
            "  {:<16} measured {:.4}s  sim {:.4}s  ({} calls)",
            op.op, op.measured_s, op.sim_s, op.count
        );
    }

    // ---- topology head-to-head: flat 8-rank ring vs 2x4 hierarchy ----
    // same model, same pipelined schedule; only the collective algorithm
    // changes, so the trajectories must stay bit-identical while the
    // two-level exchange shortens the serialized inter-host ring
    let hier_fabric = fabric
        .clone()
        .with_topology(Topology { hosts: 2, gpus_per_host: 4, segments: 2 });
    let flat8 = run(&model, 8, ExecMode::Pipelined { prefetch: 2 }, &fabric, warmup, steps)?;
    let hier8 = run(&model, 8, ExecMode::Pipelined { prefetch: 2 }, &hier_fabric, warmup, steps)?;
    let hier_identical = flat8.losses.len() == hier8.losses.len()
        && flat8
            .losses
            .iter()
            .zip(&hier8.losses)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    let hier_wins = hier8.wall_per_step < flat8.wall_per_step;
    println!(
        "\ntopology (8 ranks, pipelined-2): flat {:.4} s/step vs 2x4 {:.4} s/step \
         ({:.2}x, {})  bit-identical: {hier_identical}",
        flat8.wall_per_step,
        hier8.wall_per_step,
        flat8.wall_per_step / hier8.wall_per_step.max(1e-12),
        if hier_wins { "hierarchy wins" } else { "flat wins on this host" }
    );

    let out = Json::obj(vec![
        ("bench", Json::str("overlap_pipeline")),
        ("model", Json::str(&model)),
        ("mesh", Json::num(m as f64)),
        ("fabric", Json::str(fabric.name)),
        ("steps", Json::num(steps as f64)),
        ("host_cores", Json::num(cores as f64)),
        ("rows", Json::Arr(rows)),
        ("pipelined_wins", Json::Bool(pipelined_wins)),
        ("speedup_best_pipelined", Json::num(speedup)),
        (
            "hierarchy",
            Json::obj(vec![
                ("topology", Json::str("2x4:2")),
                ("flat_s_per_step", Json::num(flat8.wall_per_step)),
                ("hier_s_per_step", Json::num(hier8.wall_per_step)),
                (
                    "speedup_hier_vs_flat",
                    Json::num(flat8.wall_per_step / hier8.wall_per_step.max(1e-12)),
                ),
                ("hier_wins", Json::Bool(hier_wins)),
                ("bit_identical", Json::Bool(hier_identical)),
            ]),
        ),
        (
            "sim_prediction",
            Json::obj(vec![
                ("system", Json::str(sim.system)),
                ("exposed_comm_frac", Json::num(sim_exposed_frac)),
                ("step_time_s", Json::num(sim.step_time)),
                ("peak_reserved_bytes", Json::num(sim.peak_reserved as f64)),
            ]),
        ),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_overlap.json");
    std::fs::write(path, out.to_string())?;
    println!("\nwrote {path}");
    Ok(())
}
