//! Figure 9: scalability of veScale-FSDP.
//!   (a) weak scaling 1K->8K GPUs at fixed 2K-16K tokens/GPU (800B MoE)
//!   (b) strong scaling at fixed 16M/120M-token global batches
//!   (c) the same, normalized
//!   (d) model scaling 400B->2.4T on 1K GPUs (MFU per GPU)
//! plus the repo-specific hierarchy study: flat vs topology-aware
//! collectives at 1K/8K/32K-rank meshes (sim-predicted per-tier comm
//! seconds) and a measured 8-rank threaded wall for flat vs 2x4.
//!
//!     cargo bench --bench fig9_scaling [-- --steps 12 --warmup 1 --smoke]
//!
//! `--smoke` trims the measured runs and skips the fig-9 tables (the CI
//! mode); the sim sweep is analytic and runs in full either way. Emits
//! `BENCH_hierarchy.json` at the crate root.

use vescale_fsdp::baselines;
use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec, StepReport};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::json::Json;
use vescale_fsdp::util::table::{fmt_si, Table};

fn fig9_tables() {
    let fabric = Fabric::h800();
    let gpu = GpuSpec::h800();
    let ve = baselines::vescale(1);
    let preset = presets::moe_internal(800.0);

    // ---- (a) weak scaling ----
    let mut wa = Table::new(
        "Fig 9a — weak scaling, 800B MoE (tokens/s aggregate)",
        &["tokens/GPU", "1K", "2K", "4K", "8K"],
    );
    for tokens in [2048u64, 8192, 16384] {
        let mut row = vec![format!("{}", tokens)];
        for m in [1024usize, 2048, 4096, 8192] {
            let r = simulate_step(
                &preset,
                &ParallelConfig { fsdp: m, replicas: 1, ep: 8 },
                OptimKind::AdamW,
                tokens,
                &fabric,
                &gpu,
                &ve,
            )
            .unwrap();
            row.push(fmt_si(r.tokens_per_sec));
        }
        wa.row(&row);
    }
    wa.print();

    // ---- (b/c) strong scaling ----
    for global in [16_000_000u64, 120_000_000] {
        let mut sb = Table::new(
            &format!("Fig 9b/9c — strong scaling, {}M-token global batch", global / 1_000_000),
            &["GPUs", "tokens/s", "normalized (vs 1K, ideal=GPUs/1K)", "step (s)"],
        );
        let base = simulate_step(
            &preset,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
            OptimKind::AdamW,
            global / 1024,
            &fabric,
            &gpu,
            &ve,
        )
        .unwrap();
        for m in [1024usize, 2048, 4096, 8192, 10240] {
            // larger scale -> stronger EP to cap FSDP comm (paper §6.2)
            let ep = if m >= 8192 { 16 } else { 8 };
            let r = simulate_step(
                &preset,
                &ParallelConfig { fsdp: m, replicas: 1, ep },
                OptimKind::AdamW,
                global / m as u64,
                &fabric,
                &gpu,
                &ve,
            )
            .unwrap();
            sb.rowv(vec![
                format!("{m}"),
                fmt_si(r.tokens_per_sec),
                format!("{:.2}x (ideal {:.1}x)", r.tokens_per_sec / base.tokens_per_sec, m as f64 / 1024.0),
                format!("{:.2}", r.step_time),
            ]);
        }
        sb.print();
    }

    // ---- (d) model scaling on 1K GPUs ----
    let mut md = Table::new(
        "Fig 9d — model scaling on 1K GPUs (8K tokens/GPU)",
        &["model", "params", "MFU", "peak mem (GB)", "step (s)"],
    );
    for total in [400.0, 800.0, 1200.0, 2400.0] {
        let p = presets::moe_internal(total);
        let r = simulate_step(
            &p,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
            OptimKind::AdamW,
            8192,
            &fabric,
            &gpu,
            &ve,
        )
        .unwrap();
        md.rowv(vec![
            p.name.clone(),
            fmt_si(p.total_params() as f64),
            format!("{:.1}%{}", r.mfu * 100.0, if r.oom { " OOM" } else { "" }),
            format!("{:.1}", r.peak_reserved as f64 / 1e9),
            format!("{:.2}", r.step_time),
        ]);
    }
    md.print();
    println!("expected shape (paper): near-linear weak scaling; 3.4x at 16M");
    println!("batch from 1K->8K; 2.4T trains on 1K GPUs with flat-to-rising MFU.");
}

/// Sim one 800B-MoE step at mesh `m` on `fabric`.
fn sim_at(m: usize, fabric: &Fabric) -> StepReport {
    simulate_step(
        &presets::moe_internal(800.0),
        &ParallelConfig { fsdp: m, replicas: 1, ep: 8 },
        OptimKind::AdamW,
        8192,
        fabric,
        &GpuSpec::h800(),
        &baselines::vescale(1),
    )
    .unwrap()
}

/// Measured threaded-pipelined wall seconds per step on the tiny model.
fn measure(m: usize, fabric: Fabric, warmup: usize, steps: usize) -> anyhow::Result<(f64, Vec<f32>)> {
    let mut t = TrainSession::builder("tiny")
        .devices(m)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .backend(CommBackend::Threaded)
        .exec(ExecMode::Pipelined { prefetch: 2 })
        .fabric(fabric)
        .build()?;
    for _ in 0..warmup {
        t.train_step()?;
    }
    let mut losses = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for _ in 0..steps {
        losses.push(t.train_step()?);
    }
    Ok((t0.elapsed().as_secs_f64() / steps as f64, losses))
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let smoke = args.bool("smoke");
    let (steps, warmup) = if smoke {
        (args.usize_or("steps", 2), 0)
    } else {
        (args.usize_or("steps", 12), args.usize_or("warmup", 1))
    };
    if !smoke {
        fig9_tables();
    }

    // ---- hierarchy study: sim-predicted per-tier comm, flat vs HxG ----
    let mut ht = Table::new(
        "Hierarchy — flat ring vs topology-aware (sim, 800B MoE, 8K tok/GPU)",
        &["ranks", "layout", "step (s)", "comm (s)", "intra (s)", "inter (s)", "inter vs flat"],
    );
    let mut sim_rows = Vec::new();
    let mut inter_shrinks_everywhere = true;
    for m in [1024usize, 8192, 32768] {
        let flat = sim_at(m, &Fabric::h800());
        let topo = Topology { hosts: m / 8, gpus_per_host: 8, segments: 2 };
        let hier = sim_at(m, &Fabric::h800().with_topology(topo));
        let ratio = hier.inter_comm_s / flat.inter_comm_s.max(1e-12);
        inter_shrinks_everywhere &= hier.inter_comm_s < flat.inter_comm_s;
        let hier_label = format!("{}x8:2", m / 8);
        for (layout, r, rs) in [
            ("flat", &flat, "1.00x".to_string()),
            (hier_label.as_str(), &hier, format!("{ratio:.2}x")),
        ] {
            ht.rowv(vec![
                format!("{m}"),
                layout.to_string(),
                format!("{:.3}", r.step_time),
                format!("{:.3}", r.comm_time),
                format!("{:.3}", r.intra_comm_s),
                format!("{:.3}", r.inter_comm_s),
                rs,
            ]);
            sim_rows.push(Json::obj(vec![
                ("ranks", Json::num(m as f64)),
                ("layout", Json::str(layout)),
                ("step_s", Json::num(r.step_time)),
                ("comm_s", Json::num(r.comm_time)),
                ("sim_intra_comm_s", Json::num(r.intra_comm_s)),
                ("sim_inter_comm_s", Json::num(r.inter_comm_s)),
                ("inter_vs_flat", Json::num(if layout == "flat" { 1.0 } else { ratio })),
            ]));
        }
    }
    ht.print();
    println!(
        "sim-predicted inter-host comm shrinks under hierarchy at every mesh: \
         {inter_shrinks_everywhere}"
    );

    // ---- measured: 8-rank threaded wall, flat ring vs 2x4 hierarchy ----
    let (flat_wall, flat_losses) = measure(8, Fabric::h800(), warmup, steps)?;
    let (hier_wall, hier_losses) =
        measure(8, Fabric::by_name("h800:2x4:2").unwrap(), warmup, steps)?;
    let identical = flat_losses.len() == hier_losses.len()
        && flat_losses
            .iter()
            .zip(&hier_losses)
            .all(|(a, b)| a.to_bits() == b.to_bits());
    assert!(identical, "hierarchical trajectory diverged from flat");
    println!(
        "\nmeasured tiny/8 threaded pipelined: flat {:.4} s/step, 2x4 {:.4} s/step \
         ({:.2}x) — losses bit-identical: {identical}",
        flat_wall,
        hier_wall,
        flat_wall / hier_wall.max(1e-12)
    );

    let out = Json::obj(vec![
        ("bench", Json::str("fig9_scaling_hierarchy")),
        ("smoke", Json::Bool(smoke)),
        ("steps", Json::num(steps as f64)),
        ("sim_rows", Json::Arr(sim_rows)),
        ("sim_inter_comm_shrinks", Json::Bool(inter_shrinks_everywhere)),
        ("measured_flat_s_per_step", Json::num(flat_wall)),
        ("measured_2x4_s_per_step", Json::num(hier_wall)),
        ("measured_speedup_2x4_vs_flat", Json::num(flat_wall / hier_wall.max(1e-12))),
        ("losses_bit_identical", Json::Bool(identical)),
    ]);
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_hierarchy.json");
    std::fs::write(path, out.to_string())?;
    println!("wrote {path}");
    Ok(())
}
