//! Figure 9: scalability of veScale-FSDP.
//!   (a) weak scaling 1K->8K GPUs at fixed 2K-16K tokens/GPU (800B MoE)
//!   (b) strong scaling at fixed 16M/120M-token global batches
//!   (c) the same, normalized
//!   (d) model scaling 400B->2.4T on 1K GPUs (MFU per GPU)

use vescale_fsdp::baselines;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::util::table::{fmt_si, Table};

fn main() {
    let fabric = Fabric::h800();
    let gpu = GpuSpec::h800();
    let ve = baselines::vescale(1);
    let preset = presets::moe_internal(800.0);

    // ---- (a) weak scaling ----
    let mut wa = Table::new(
        "Fig 9a — weak scaling, 800B MoE (tokens/s aggregate)",
        &["tokens/GPU", "1K", "2K", "4K", "8K"],
    );
    for tokens in [2048u64, 8192, 16384] {
        let mut row = vec![format!("{}", tokens)];
        for m in [1024usize, 2048, 4096, 8192] {
            let r = simulate_step(
                &preset,
                &ParallelConfig { fsdp: m, replicas: 1, ep: 8 },
                OptimKind::AdamW,
                tokens,
                &fabric,
                &gpu,
                &ve,
            )
            .unwrap();
            row.push(fmt_si(r.tokens_per_sec));
        }
        wa.row(&row);
    }
    wa.print();

    // ---- (b/c) strong scaling ----
    for global in [16_000_000u64, 120_000_000] {
        let mut sb = Table::new(
            &format!("Fig 9b/9c — strong scaling, {}M-token global batch", global / 1_000_000),
            &["GPUs", "tokens/s", "normalized (vs 1K, ideal=GPUs/1K)", "step (s)"],
        );
        let base = simulate_step(
            &preset,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
            OptimKind::AdamW,
            global / 1024,
            &fabric,
            &gpu,
            &ve,
        )
        .unwrap();
        for m in [1024usize, 2048, 4096, 8192, 10240] {
            // larger scale -> stronger EP to cap FSDP comm (paper §6.2)
            let ep = if m >= 8192 { 16 } else { 8 };
            let r = simulate_step(
                &preset,
                &ParallelConfig { fsdp: m, replicas: 1, ep },
                OptimKind::AdamW,
                global / m as u64,
                &fabric,
                &gpu,
                &ve,
            )
            .unwrap();
            sb.rowv(vec![
                format!("{m}"),
                fmt_si(r.tokens_per_sec),
                format!("{:.2}x (ideal {:.1}x)", r.tokens_per_sec / base.tokens_per_sec, m as f64 / 1024.0),
                format!("{:.2}", r.step_time),
            ]);
        }
        sb.print();
    }

    // ---- (d) model scaling on 1K GPUs ----
    let mut md = Table::new(
        "Fig 9d — model scaling on 1K GPUs (8K tokens/GPU)",
        &["model", "params", "MFU", "peak mem (GB)", "step (s)"],
    );
    for total in [400.0, 800.0, 1200.0, 2400.0] {
        let p = presets::moe_internal(total);
        let r = simulate_step(
            &p,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
            OptimKind::AdamW,
            8192,
            &fabric,
            &gpu,
            &ve,
        )
        .unwrap();
        md.rowv(vec![
            p.name.clone(),
            fmt_si(p.total_params() as f64),
            format!("{:.1}%{}", r.mfu * 100.0, if r.oom { " OOM" } else { "" }),
            format!("{:.1}", r.peak_reserved as f64 / 1e9),
            format!("{:.2}", r.step_time),
        ]);
    }
    md.print();
    println!("expected shape (paper): near-linear weak scaling; 3.4x at 16M");
    println!("batch from 1K->8K; 2.4T trains on 1K GPUs with flat-to-rising MFU.");
}
