//! Figure 8: end-to-end FSDP training performance — normalized aggregate
//! throughput (top row) and peak per-GPU memory (bottom row) for the five
//! systems on LLaMA-3-70B, GPT-OSS-120B, and the internal-style MoE, at
//! FSDP 128/256 and HSDP 2x256 / 4x256.

use vescale_fsdp::baselines;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::util::table::Table;

fn main() {
    let fabric = Fabric::h800();
    let gpu = GpuSpec::h800();
    let layouts = [
        ParallelConfig { fsdp: 128, replicas: 1, ep: 1 },
        ParallelConfig { fsdp: 256, replicas: 1, ep: 1 },
        ParallelConfig { fsdp: 256, replicas: 2, ep: 1 },
        ParallelConfig { fsdp: 256, replicas: 4, ep: 1 },
    ];
    let systems: Vec<_> = baselines::all_baselines()
        .into_iter()
        .chain([baselines::vescale(1)])
        .collect();

    for (preset, tokens, optim) in [
        (presets::llama70b(), 4096u64, OptimKind::AdamW),
        // paper: SGD fallback so the baselines avoid OOM on GPT-OSS
        (presets::gptoss120b(), 8192, OptimKind::Sgd),
        (presets::moe_internal(800.0), 8192, OptimKind::Sgd),
    ] {
        let mut tput = Table::new(
            &format!("Fig 8 (top) — {}: normalized tokens/s (AdamW/SGD per paper)", preset.name),
            &["system", "FSDP 128", "FSDP 256", "HSDP 2x256", "HSDP 4x256"],
        );
        let mut mem = Table::new(
            &format!("Fig 8 (bottom) — {}: peak per-GPU memory (GB)", preset.name),
            &["system", "FSDP 128", "FSDP 256", "HSDP 2x256", "HSDP 4x256"],
        );
        // normalize throughput to veScale at FSDP 128
        let ve128 = simulate_step(&preset, &layouts[0], optim, tokens, &fabric, &gpu,
                                  &baselines::vescale(1)).unwrap();
        for sys in &systems {
            let mut trow = vec![sys.name.to_string()];
            let mut mrow = vec![sys.name.to_string()];
            for l in &layouts {
                let r = simulate_step(&preset, l, optim, tokens, &fabric, &gpu, sys).unwrap();
                if r.oom {
                    trow.push("OOM".into());
                    mrow.push("OOM".into());
                } else {
                    let devs = l.total_devices() as f64 / 128.0;
                    trow.push(format!("{:.1}%", r.tokens_per_sec / (ve128.tokens_per_sec * devs) * 100.0));
                    mrow.push(format!("{:.1}", r.peak_reserved as f64 / 1e9));
                }
            }
            tput.row(&trow);
            mem.row(&mrow);
        }
        tput.print();
        mem.print();
    }
    println!("expected shape (paper): veScale 5% faster on dense, 11-66% on MoE;");
    println!("16-30% lower memory; FSDP2 OOMs GPT-OSS at 256 devices.");
}
