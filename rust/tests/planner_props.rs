//! Property-based tests for the planner (Algorithm 1) against the exact
//! exponential solver, using the in-crate mini property harness.

use vescale_fsdp::planner::{
    check_valid_shard, naive_concat_shard, plan, solve_exact, split_blocks, TensorDecl,
};
use vescale_fsdp::util::prop::{check, Case};

fn random_instance(c: &mut Case) -> (Vec<TensorDecl>, usize) {
    let n = c.rng.range(1, 5.min(c.scaled(5)).max(1));
    let m = c.rng.range(2, 4);
    let grans = [1u64, 2, 4, 8, 16];
    let tensors = (0..n)
        .map(|i| {
            let g = *c.rng.pick(&grans);
            let blocks = c.rng.range(1, c.scaled(12).max(1)) as u64;
            TensorDecl::new(&format!("t{i}"), g * blocks, g)
        })
        .collect();
    (tensors, m)
}

#[test]
fn planner_layout_always_satisfies_constraints() {
    check("layout-valid", 200, |c| {
        let (tensors, m) = random_instance(c);
        let layout = plan(&tensors, m, 1).map_err(|e| e.to_string())?;
        layout.verify().map_err(|e| format!("invalid layout: {e}"))?;
        if split_blocks(&layout) != 0 {
            return Err("planner split a block".into());
        }
        Ok(())
    });
}

#[test]
fn planner_within_2x_of_exact_optimum() {
    check("2-approx", 120, |c| {
        let (tensors, m) = random_instance(c);
        let layout = plan(&tensors, m, 1).map_err(|e| e.to_string())?;
        let exact = solve_exact(&tensors, m, 1)
            .ok_or_else(|| "exact solver found nothing".to_string())?;
        if layout.shard_size > 2 * exact {
            return Err(format!(
                "heuristic {} > 2x exact {} for {:?}",
                layout.shard_size, exact, tensors
            ));
        }
        Ok(())
    });
}

#[test]
fn feasibility_monotone_in_multiples_of_lcm() {
    // paper §5: if kL is feasible then (k+1)L is feasible
    check("monotone-S", 150, |c| {
        let (tensors, m) = random_instance(c);
        let l = tensors.iter().fold(1u64, |acc, t| {
            vescale_fsdp::util::lcm(acc, t.granularity)
        });
        let refs: Vec<&TensorDecl> = tensors.iter().collect();
        let sum: u64 = tensors.iter().map(|t| t.numel).sum();
        let mut feasible_seen = false;
        for k in 1..=(sum / l + 2) {
            let ok = check_valid_shard(&refs, m, k * l, None).is_some();
            if feasible_seen && !ok {
                return Err(format!("feasibility not monotone at k={k}, L={l}"));
            }
            feasible_seen |= ok;
        }
        if !feasible_seen {
            return Err("no feasible multiple of LCM found".into());
        }
        Ok(())
    });
}

#[test]
fn dp_trace_monotone_in_blocks() {
    check("dp-monotone", 150, |c| {
        let (tensors, m) = random_instance(c);
        let refs: Vec<&TensorDecl> = tensors.iter().collect();
        let sum: u64 = tensors.iter().map(|t| t.numel).sum();
        let s = (sum / m as u64).max(1) * 2;
        let mut trace = Vec::new();
        if check_valid_shard(&refs, m, s, Some(&mut trace)).is_some() {
            for w in trace.windows(2) {
                if w[0] > w[1] {
                    return Err(format!("dp not monotone: {trace:?}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn planner_never_worse_than_naive_padding() {
    check("beats-naive", 150, |c| {
        let (tensors, m) = random_instance(c);
        let planned = plan(&tensors, m, 1).map_err(|e| e.to_string())?;
        let naive = naive_concat_shard(&tensors, m, 1);
        // naive ignores the block constraint entirely, so compare on the
        // only dimension where it is honest: planned must not exceed naive
        // by more than the largest granularity (the alignment it buys)
        let max_g = tensors.iter().map(|t| t.granularity).max().unwrap_or(1);
        if planned.shard_size > naive.shard_size + max_g * m as u64 {
            return Err(format!(
                "planned {} vs naive {} (max_g {max_g})",
                planned.shard_size, naive.shard_size
            ));
        }
        Ok(())
    });
}

#[test]
fn ragged_specs_partition_every_tensor() {
    check("specs-partition", 150, |c| {
        let (tensors, m) = random_instance(c);
        let layout = plan(&tensors, m, 1).map_err(|e| e.to_string())?;
        for (i, t) in tensors.iter().enumerate() {
            let spec = layout.ragged_spec(i);
            spec.validate(t.numel).map_err(|e| e.to_string())?;
            let covered: u64 = (0..m).map(|k| spec.local_numel(k, t.numel)).sum();
            if covered != t.numel {
                return Err(format!("tensor {i} covered {covered}/{}", t.numel));
            }
        }
        Ok(())
    });
}

#[test]
fn zero_padding_when_everything_divides() {
    // uniform tensors, granularity dividing everything -> optimal S with
    // no padding at all
    check("no-pad-uniform", 80, |c| {
        let m = c.rng.range(2, 4);
        let g = *c.rng.pick(&[1u64, 2, 4]);
        let per = g * c.rng.range(1, 8) as u64;
        let n = m * c.rng.range(1, 4);
        let tensors: Vec<TensorDecl> = (0..n)
            .map(|i| TensorDecl::new(&format!("t{i}"), per, g))
            .collect();
        let layout = plan(&tensors, m, 1).map_err(|e| e.to_string())?;
        let total: u64 = tensors.iter().map(|t| t.numel).sum();
        if layout.shard_size != total / m as u64 {
            return Err(format!(
                "expected perfect packing {} got {}",
                total / m as u64,
                layout.shard_size
            ));
        }
        Ok(())
    });
}
