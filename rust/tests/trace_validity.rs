//! Trace validity: the Chrome trace export must parse, validate
//! (strict per-lane nesting, bucket/bytes attribution on collective
//! spans), agree across cluster backends, and never perturb training —
//! tracing on vs off is bit-identical. Also the satellite invariant:
//! `ExecReport::exposed_comm_s` *is* the sum of exposed-span durations.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::trace::{check, TraceLevel};
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::json::Json;

fn session(backend: CommBackend, exec: ExecMode, level: TraceLevel) -> TrainSession {
    TrainSession::builder("tiny")
        .devices(2)
        .seed(11)
        .backend(backend)
        .exec(exec)
        .trace(level)
        .build()
        .unwrap()
}

fn losses(s: &TrainSession) -> Vec<u32> {
    s.log.iter().map(|l| l.loss.to_bits()).collect()
}

#[test]
fn pipelined_trace_exports_valid_chrome_json() {
    let mut s = session(
        CommBackend::Threaded,
        ExecMode::Pipelined { prefetch: 2 },
        TraceLevel::Full,
    );
    s.run(2).unwrap();
    // round-trip through text: what CI's trace-check sees is what we check
    let text = s.trace_json().to_string();
    let doc = Json::parse(&text).unwrap();
    check::validate(&doc).unwrap();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();

    // one pid per rank plus the fabric pid
    let pids: Vec<&str> = events
        .iter()
        .filter(|e| {
            e.get("ph").and_then(Json::as_str) == Some("M")
                && e.get("name").and_then(Json::as_str) == Some("process_name")
        })
        .map(|e| e.get("args").unwrap().get("name").unwrap().as_str().unwrap())
        .collect();
    assert!(pids.contains(&"rank0") && pids.contains(&"rank1"), "{pids:?}");
    assert!(pids.contains(&"fabric"), "{pids:?}");

    // counter tracks sampled each step
    let counters: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for want in ["mem.reserved", "mem.allocated", "wire.payload"] {
        assert!(counters.contains(&want), "missing counter {want}");
    }

    // the full schedule vocabulary shows up
    let names: Vec<&str> = events
        .iter()
        .filter(|e| e.get("ph").and_then(Json::as_str) == Some("X"))
        .map(|e| e.get("name").unwrap().as_str().unwrap())
        .collect();
    for want in ["ag", "rs", "fwd", "bwd", "optim", "all_gather", "reduce_scatter"] {
        assert!(names.contains(&want), "missing span {want}");
    }
}

#[test]
fn collective_spans_carry_bucket_and_bytes() {
    let mut s = session(
        CommBackend::Serial,
        ExecMode::Pipelined { prefetch: 1 },
        TraceLevel::Comm,
    );
    s.run(1).unwrap();
    let doc = s.trace_json();
    let events = doc.get("traceEvents").unwrap().as_arr().unwrap();
    let mut seen = 0;
    for e in events {
        let name = e.get("name").and_then(Json::as_str).unwrap_or("");
        if e.get("ph").and_then(Json::as_str) == Some("X") && (name == "ag" || name == "rs") {
            let args = e.get("args").expect("collective span args");
            let bucket = args.get("bucket").and_then(Json::as_str).expect("bucket");
            assert!(!bucket.is_empty());
            let bytes = args.get("bytes").and_then(Json::as_f64).expect("bytes");
            assert!(bytes > 0.0, "span {name} bucket {bucket}: bytes {bytes}");
            seen += 1;
        }
    }
    // tiny = 4 buckets, each gathered in fwd + bwd and reduced once
    assert!(seen >= 8, "only {seen} collective spans");
}

#[test]
fn serial_and_threaded_traces_agree() {
    let run = |backend| {
        let mut s = session(backend, ExecMode::Pipelined { prefetch: 2 }, TraceLevel::Full);
        s.run(2).unwrap();
        (losses(&s), s.tracer.span_identities())
    };
    let (loss_ser, spans_ser) = run(CommBackend::Serial);
    let (loss_thr, spans_thr) = run(CommBackend::Threaded);
    assert_eq!(loss_ser, loss_thr, "backend changed the trajectory");
    assert_eq!(
        spans_ser.len(),
        spans_thr.len(),
        "backend changed the span count"
    );
    // identical multiset of (name, bucket, bytes): both backends ran the
    // same schedule and shipped the same wire volume
    assert_eq!(spans_ser, spans_thr);
}

#[test]
fn tracing_is_bitwise_invisible() {
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 2 }),
    ] {
        let mut off = session(backend, exec, TraceLevel::Off);
        off.run(2).unwrap();
        let mut full = session(backend, exec, TraceLevel::Full);
        full.run(2).unwrap();
        assert_eq!(off.tracer.span_count(), 0);
        assert!(full.tracer.span_count() > 0);
        assert_eq!(
            losses(&off),
            losses(&full),
            "{} {}: tracing perturbed the losses",
            backend.name(),
            exec.name()
        );
    }
}

#[test]
fn exposed_comm_derives_from_spans() {
    for exec in [ExecMode::Sequential, ExecMode::Pipelined { prefetch: 2 }] {
        let mut s = session(CommBackend::Threaded, exec, TraceLevel::Comm);
        s.run(2).unwrap();
        let from_report: f64 = s.log.iter().map(|l| l.exposed_s).sum();
        let from_spans = s.tracer.exposed_total_s();
        assert!(from_report > 0.0, "{}: no exposed comm measured", exec.name());
        assert!(
            (from_report - from_spans).abs() < 1e-9,
            "{}: report {from_report} != span sum {from_spans}",
            exec.name()
        );
        // the summary agrees and stays on [0, 1]
        let sum = s.trace_summary();
        assert!((sum.exposed_comm_s - from_spans).abs() < 1e-9);
        assert!((0.0..=1.0).contains(&sum.overlap_efficiency));
        assert!(sum.total_comm_s > 0.0);
    }
}

#[test]
fn steplog_records_allocator_peaks() {
    let mut s = session(
        CommBackend::Serial,
        ExecMode::Pipelined { prefetch: 1 },
        TraceLevel::Off,
    );
    s.run(1).unwrap();
    let l = &s.log[0];
    assert!(l.peak_allocated > 0);
    assert!(l.peak_reserved >= l.peak_allocated);
}

#[test]
fn validator_rejects_partial_overlap_and_bad_spans() {
    let xev = |name: &str, ts: f64, dur: f64| {
        Json::obj(vec![
            ("ph", Json::str("X")),
            ("pid", Json::num(0.0)),
            ("tid", Json::num(2.0)),
            ("ts", Json::num(ts)),
            ("dur", Json::num(dur)),
            ("name", Json::str(name)),
            ("cat", Json::str("comm")),
            (
                "args",
                Json::obj(vec![
                    ("bucket", Json::str("b")),
                    ("bytes", Json::num(8.0)),
                ]),
            ),
        ])
    };
    let doc = |events| Json::obj(vec![("traceEvents", Json::Arr(events))]);
    // partial overlap on one lane: neither contains the other
    let bad = doc(vec![xev("ag", 0.0, 100.0), xev("rs", 50.0, 100.0)]);
    assert!(check::validate(&bad).is_err());
    // same intervals on different lanes are fine
    let mut other = xev("rs", 50.0, 100.0);
    if let Json::Obj(map) = &mut other {
        map.insert("tid".into(), Json::num(3.0));
    }
    let ok = doc(vec![xev("ag", 0.0, 100.0), other]);
    check::validate(&ok).unwrap();
    // a collective span without attribution is rejected
    let naked = Json::obj(vec![
        ("ph", Json::str("X")),
        ("pid", Json::num(0.0)),
        ("tid", Json::num(2.0)),
        ("ts", Json::num(0.0)),
        ("dur", Json::num(1.0)),
        ("name", Json::str("ag")),
        ("cat", Json::str("comm")),
    ]);
    assert!(check::validate(&doc(vec![naked])).is_err());
}
