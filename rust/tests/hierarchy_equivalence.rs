//! Hierarchy equivalence: the topology-aware two-level collectives must be
//! **bit-identical** to the flat single-ring algorithms — property tests
//! over ragged shard sizes × mesh sizes {2,4,8} × topologies
//! {1×m, 2×(m/2), 4×(m/4)} × pipeline segment counts S ∈ {1,2,4}, on the
//! sync and async dispatch paths, plus end-to-end training trajectories
//! (losses and final parameters to the bit) across cluster backends,
//! executor schedules, and wire precisions with a hierarchical fabric.

use vescale_fsdp::cluster::{CommBackend, CommBuilder, Communicator, SerialComm};
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::prop::check;
use vescale_fsdp::util::Rng;

const MESHES: [usize; 3] = [2, 4, 8];
const SEGMENTS: [usize; 3] = [1, 2, 4];

/// The threaded backend only engages the rendezvous (and hierarchical)
/// algorithms above its serial-fallback threshold of 16 Ki total elements
/// (`m * m * s`); sizes below it run the flat serial loop, which is
/// trivially identical. Pick shard sizes just above the threshold so the
/// two-level path actually executes.
fn min_shard(m: usize) -> usize {
    (16 * 1024).div_ceil(m * m)
}

/// Magnitudes spread over many exponents: any change in summation order
/// would actually flip result bits.
fn wild_bufs(rng: &mut Rng, m: usize, len: usize) -> Vec<Vec<f32>> {
    (0..m)
        .map(|_| {
            (0..len)
                .map(|_| rng.normal_f32() * 10f32.powi(rng.below(9) as i32 - 4))
                .collect()
        })
        .collect()
}

/// All host layouts of `m` ranks the issue sweeps: the flat degenerate
/// case plus every multi-host factorization with 2 or 4 hosts.
fn topologies(m: usize, segments: usize) -> Vec<Topology> {
    [1usize, 2, 4]
        .into_iter()
        .filter(|&hosts| m % hosts == 0 && m / hosts >= 1)
        .map(|hosts| Topology { hosts, gpus_per_host: m / hosts, segments })
        .collect()
}

fn assert_bits_equal(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) -> Result<(), String> {
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        for (i, (u, v)) in x.iter().zip(y).enumerate() {
            if u.to_bits() != v.to_bits() {
                return Err(format!("{what}: rank {k} elem {i}: {u} vs {v}"));
            }
        }
    }
    Ok(())
}

#[test]
fn hierarchical_all_gather_bit_identical_to_flat() {
    check("hier-ag-equiv", 12, |case| {
        let m = MESHES[case.rng.below(MESHES.len() as u64) as usize];
        let s = min_shard(m) + case.rng.range(0, 37);
        let seed = case.rng.below(u64::MAX / 2);
        let mut want = wild_bufs(&mut Rng::new(seed), m, m * s);
        SerialComm::new().all_gather(&mut want, s).map_err(|e| e.to_string())?;
        for &segs in &SEGMENTS {
            for topo in topologies(m, segs) {
                let what = format!("ag m={m} s={s} topo={}:{segs}", topo.label());
                let c = CommBuilder::new(CommBackend::Threaded).topology(topo).build();
                let mut got = wild_bufs(&mut Rng::new(seed), m, m * s);
                c.all_gather(&mut got, s).map_err(|e| e.to_string())?;
                assert_bits_equal(&want, &got, &format!("{what} sync"))?;
                let got = c
                    .all_gather_async(wild_bufs(&mut Rng::new(seed), m, m * s), s)
                    .wait()
                    .map_err(|e| e.to_string())?;
                assert_bits_equal(&want, &got, &format!("{what} async"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn hierarchical_reduce_scatter_bit_identical_to_flat() {
    check("hier-rs-equiv", 12, |case| {
        let m = MESHES[case.rng.below(MESHES.len() as u64) as usize];
        let s = min_shard(m) + case.rng.range(0, 37);
        let seed = case.rng.below(u64::MAX / 2);
        let scale = 1.0 / m as f32;
        let mut want = wild_bufs(&mut Rng::new(seed), m, m * s);
        SerialComm::new()
            .reduce_scatter(&mut want, s, scale)
            .map_err(|e| e.to_string())?;
        for &segs in &SEGMENTS {
            for topo in topologies(m, segs) {
                let what = format!("rs m={m} s={s} topo={}:{segs}", topo.label());
                let c = CommBuilder::new(CommBackend::Threaded).topology(topo).build();
                let mut got = wild_bufs(&mut Rng::new(seed), m, m * s);
                c.reduce_scatter(&mut got, s, scale).map_err(|e| e.to_string())?;
                assert_bits_equal(&want, &got, &format!("{what} sync"))?;
                let got = c
                    .reduce_scatter_async(wild_bufs(&mut Rng::new(seed), m, m * s), s, scale)
                    .wait()
                    .map_err(|e| e.to_string())?;
                assert_bits_equal(&want, &got, &format!("{what} async"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn segment_count_never_changes_bits() {
    // chunk pipelining is pure scheduling: S=1/2/4 must produce the exact
    // same bytes, compared directly against each other (not just
    // transitively through the oracle)
    let (m, s) = (8usize, 300usize);
    let mut rng = Rng::new(77);
    let data = wild_bufs(&mut rng, m, m * s);
    let run = |segments: usize, op_is_ag: bool| -> Vec<Vec<f32>> {
        let topo = Topology { hosts: 2, gpus_per_host: 4, segments };
        let c = CommBuilder::new(CommBackend::Threaded).topology(topo).build();
        let mut bufs = data.clone();
        if op_is_ag {
            c.all_gather(&mut bufs, s).unwrap();
        } else {
            c.reduce_scatter(&mut bufs, s, 0.125).unwrap();
        }
        bufs
    };
    for op_is_ag in [true, false] {
        let s1 = run(1, op_is_ag);
        for segments in [2usize, 4] {
            let sn = run(segments, op_is_ag);
            assert_bits_equal(&s1, &sn, &format!("ag={op_is_ag} S={segments}")).unwrap();
        }
    }
}

// ---- end-to-end trajectories --------------------------------------------

fn run_session(
    backend: CommBackend,
    exec: ExecMode,
    prec: CommPrecision,
    fabric: Fabric,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>, String) {
    let mut t = TrainSession::builder("tiny")
        .devices(4)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .backend(backend)
        .exec(exec)
        .fabric(fabric)
        .comm_precision(prec)
        .build()
        .unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let params = (0..t.engine.params.len())
        .map(|i| t.engine.read_param(i))
        .collect();
    let topology_col = t.log.last().map(|l| l.topology.clone()).unwrap_or_default();
    (losses, params, topology_col)
}

fn assert_trajectories_equal(
    a: &(Vec<f32>, Vec<Vec<f32>>, String),
    b: &(Vec<f32>, Vec<Vec<f32>>, String),
    what: &str,
) {
    assert_eq!(a.0.len(), b.0.len(), "{what}: loss count");
    for (step, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss {step}: {x} vs {y}");
    }
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        for (x, y) in pa.iter().zip(pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}");
        }
    }
}

#[test]
fn hierarchical_training_bit_identical_across_backends_and_schedules() {
    // a 2x2 topology exactly covers the 4-device mesh, so whole-cluster
    // parameter/gradient collectives dispatch hierarchically on the
    // threaded backend; the trajectory must not move by a single bit vs
    // the flat serial-sequential reference — for every wire precision
    for prec in [
        CommPrecision::F32,
        CommPrecision::Bf16,
        CommPrecision::Q8 { block: 64 },
    ] {
        let reference = run_session(
            CommBackend::Serial,
            ExecMode::Sequential,
            prec,
            Fabric::h800(),
            2,
        );
        assert_eq!(reference.2, "flat", "flat fabric logs topology=flat");
        for (backend, exec) in [
            (CommBackend::Serial, ExecMode::Sequential),
            (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
            (CommBackend::Threaded, ExecMode::Sequential),
            (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 1 }),
        ] {
            let hier = Fabric::by_name("h800:2x2:2").unwrap();
            let r = run_session(backend, exec, prec, hier, 2);
            assert_eq!(r.2, "2x2", "hierarchical fabric logs its topology");
            assert_trajectories_equal(
                &reference,
                &r,
                &format!("{} {} {}", prec.name(), backend.name(), exec.name()),
            );
        }
    }
}

#[test]
fn fabric_topology_suffix_parses_and_degenerates() {
    // `--fabric h800:2x4:2` style suffixes attach a topology; hosts=1 is
    // byte-for-byte the flat fabric
    let f = Fabric::by_name("h800:2x4:2").unwrap();
    assert_eq!(f.topology, Topology { hosts: 2, gpus_per_host: 4, segments: 2 });
    assert!(f.is_hier(8));
    assert!(!f.is_hier(4), "partial groups keep the flat model");
    let flat = Fabric::by_name("h800:1x8").unwrap();
    assert!(!flat.topology.is_hierarchical());
    assert!(Fabric::by_name("h800:0x4").is_none());
    assert!(Fabric::by_name("h800:ring").is_none());
}
