//! Seeded-interleaving stress test for the ThreadedComm rendezvous
//! protocol: `cluster::set_arrival_stagger` delays each rank's entry
//! into every collective by a seeded permutation of arrival order, so
//! barrier phases are exercised under adversarial thread schedules. The
//! properties under test are exactly the two the static analyzer proves
//! on the schedule level (`analysis::checks::check_spmd`): every
//! collective terminates regardless of arrival order (rendezvous
//! deadlock-freedom), and the results stay bit-identical to the serial
//! backend (the protocol's disjointness argument holds under any
//! interleaving).

use vescale_fsdp::cluster::{
    set_arrival_stagger, CommBackend, CommBuilder, Communicator, ThreadedComm,
};
use vescale_fsdp::comm::Topology;
use vescale_fsdp::util::Rng;

/// Seeded per-rank buffers, identical for every backend under test.
fn seeded_bufs(m: usize, len: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| (0..len).map(|_| rng.normal_f32()).collect())
        .collect()
}

/// Arrival delays realizing a seeded permutation of rank arrival order:
/// the rank drawn first enters immediately, the next 100us later, etc.
fn stagger_for(m: usize, rng: &mut Rng) -> Vec<u64> {
    let mut order: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut order);
    let mut delays = vec![0u64; m];
    for (slot, &rank) in order.iter().enumerate() {
        delays[rank] = 100 * slot as u64;
    }
    delays
}

/// Run every collective on both backends from identical inputs and
/// demand bit-identical outputs. `s` is the shard size; AllGather inputs
/// only populate each rank's own shard (the gather contract).
fn assert_collectives_match(threaded: &dyn Communicator, m: usize, s: usize, seed: u64) {
    let serial = CommBuilder::new(CommBackend::Serial).build();

    // AllGather: rank k owns bufs[k][k*s..(k+1)*s]
    let mut a = seeded_bufs(m, m * s, seed);
    for (k, b) in a.iter_mut().enumerate() {
        for (i, x) in b.iter_mut().enumerate() {
            if i / s != k {
                *x = 0.0;
            }
        }
    }
    let mut b = a.clone();
    threaded.all_gather(&mut a, s).unwrap();
    serial.all_gather(&mut b, s).unwrap();
    assert_eq!(a, b, "all_gather diverged (m={m} s={s})");

    // ReduceScatter (sum, scaled)
    let mut a = seeded_bufs(m, m * s, seed ^ 1);
    let mut b = a.clone();
    threaded.reduce_scatter(&mut a, s, 1.0 / m as f32).unwrap();
    serial.reduce_scatter(&mut b, s, 1.0 / m as f32).unwrap();
    assert_eq!(a, b, "reduce_scatter diverged (m={m} s={s})");

    // AllReduce over whole buffers
    let mut a = seeded_bufs(m, m * s, seed ^ 2);
    let mut b = a.clone();
    threaded.all_reduce(&mut a, 0.5).unwrap();
    serial.all_reduce(&mut b, 0.5).unwrap();
    assert_eq!(a, b, "all_reduce diverged (m={m} s={s})");

    // Broadcast from a seed-dependent root
    let mut a = seeded_bufs(m, m * s, seed ^ 3);
    let mut b = a.clone();
    let root = (seed as usize) % m;
    threaded.broadcast(&mut a, root).unwrap();
    serial.broadcast(&mut b, root).unwrap();
    assert_eq!(a, b, "broadcast diverged (m={m} root={root})");

    // All-to-all slot exchange
    let mut a = seeded_bufs(m, m * s, seed ^ 4);
    let mut b = a.clone();
    threaded.all_to_all(&mut a, s).unwrap();
    serial.all_to_all(&mut b, s).unwrap();
    assert_eq!(a, b, "all_to_all diverged (m={m} s={s})");
}

fn stress_flat(m: usize, trials: u64) {
    // threshold 0 forces the rendezvous algorithms even for tiny buffers
    // (the serial fallback would dodge the very races under test)
    let threaded = ThreadedComm::with_min_parallel_elems(0);
    let mut rng = Rng::new(0xC0FFEE ^ m as u64);
    for trial in 0..trials {
        let delays = stagger_for(m, &mut rng);
        set_arrival_stagger(&delays);
        // odd shard size: chunk boundaries land mid-cacheline, and the
        // ring steps move unaligned regions
        assert_collectives_match(&threaded, m, 33, trial);
    }
    set_arrival_stagger(&[]);
}

#[test]
fn rendezvous_survives_arrival_permutations_m4() {
    stress_flat(4, 12);
}

#[test]
fn rendezvous_survives_arrival_permutations_m8() {
    stress_flat(8, 12);
}

#[test]
fn hierarchical_rendezvous_survives_stagger() {
    // 2 hosts x 4 GPUs, 2 pipeline segments: whole-cluster AG/RS take the
    // two-level path (s large enough to clear the serial-fallback
    // threshold), still bit-identical to serial under staggered arrival.
    let topo = Topology { hosts: 2, gpus_per_host: 4, segments: 2 };
    let threaded = CommBuilder::new(CommBackend::Threaded).topology(topo).build();
    let m = topo.total();
    let s = 512;
    let serial = CommBuilder::new(CommBackend::Serial).build();
    let mut rng = Rng::new(0xD15C0);
    for trial in 0..8u64 {
        let delays = stagger_for(m, &mut rng);
        set_arrival_stagger(&delays);

        let mut a = seeded_bufs(m, m * s, trial);
        for (k, b) in a.iter_mut().enumerate() {
            for (i, x) in b.iter_mut().enumerate() {
                if i / s != k {
                    *x = 0.0;
                }
            }
        }
        let mut b = a.clone();
        threaded.all_gather(&mut a, s).unwrap();
        serial.all_gather(&mut b, s).unwrap();
        assert_eq!(a, b, "hierarchical all_gather diverged (trial {trial})");

        let mut a = seeded_bufs(m, m * s, trial ^ 0xAB);
        let mut b = a.clone();
        threaded.reduce_scatter(&mut a, s, 1.0 / m as f32).unwrap();
        serial.reduce_scatter(&mut b, s, 1.0 / m as f32).unwrap();
        assert_eq!(a, b, "hierarchical reduce_scatter diverged (trial {trial})");
    }
    set_arrival_stagger(&[]);
}

#[test]
fn stagger_hook_is_scoped_to_the_setting_thread() {
    // another thread's collectives must not observe this thread's delays
    set_arrival_stagger(&[200_000; 4]);
    let t0 = std::time::Instant::now();
    std::thread::spawn(|| {
        let threaded = ThreadedComm::with_min_parallel_elems(0);
        let mut bufs = seeded_bufs(4, 4 * 16, 9);
        threaded.all_reduce(&mut bufs, 1.0).unwrap();
    })
    .join()
    .unwrap();
    // a leak would add >= 200ms of concurrent sleeps to every fan-out
    assert!(t0.elapsed().as_millis() < 150, "stagger leaked across threads");
    set_arrival_stagger(&[]);
}
