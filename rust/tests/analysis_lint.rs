//! Seeded-defect fixtures for the static analyzer: each fixture plants
//! exactly one protocol defect into an otherwise-clean elaborated
//! program (or plan) and asserts the analyzer reports exactly the
//! expected diagnostic code — no false positives from the untouched
//! checks, no misclassification. The closing property test sweeps every
//! shipped preset x backend x exec-mode x precision x topology combo
//! (the same matrix the CI `plan-lint` job runs via `fsdp-lint
//! --matrix`) and demands a clean report for all of them.

use vescale_fsdp::analysis::diag::codes;
use vescale_fsdp::analysis::ir::{ClaimId, CollOp, Phase};
use vescale_fsdp::analysis::{
    elaborate, lint, run_checks, AnalysisReport, Event, LintRequest, PlanModel,
};
use vescale_fsdp::cluster::{CommBackend, DEFAULT_HIER_THRESHOLD};
use vescale_fsdp::comm::Topology;
use vescale_fsdp::config::presets;
use vescale_fsdp::fsdp::{ExecMode, DEVICE_MEM_LIMIT};
use vescale_fsdp::quant::CommPrecision;

/// Build the clean base plan every fixture mutates: the `tiny` preset
/// on an 8-rank flat mesh.
fn tiny_plan(exec: ExecMode, prec: CommPrecision, mem_limit: u64) -> PlanModel {
    let preset = presets::by_name("tiny").expect("tiny preset shipped");
    let params = preset.param_table();
    let mut spec = preset.shard_spec();
    for g in spec.groups.iter_mut() {
        g.comm_precision = prec;
    }
    PlanModel::build(&LintRequest {
        model: "tiny",
        params: &params,
        spec: &spec,
        devices: 8,
        replicas: 1,
        backend: CommBackend::Serial,
        exec,
        topology: Topology::flat(),
        hier_threshold: DEFAULT_HIER_THRESHOLD,
        native_layers: None,
        mem_limit,
    })
    .unwrap_or_else(|d| panic!("tiny plan should build: {d}"))
}

/// The fixture contract: at least one diagnostic, and every diagnostic
/// carries the planted defect's code.
fn assert_only_code(report: &AnalysisReport, code: &str, fixture: &str) {
    assert!(
        !report.diagnostics.is_empty(),
        "{fixture}: expected {code} but the report is clean"
    );
    for d in &report.diagnostics {
        assert_eq!(d.code, code, "{fixture}: expected only {code}, got: {d}");
    }
}

#[test]
fn clean_base_plans_lint_clean() {
    for exec in [ExecMode::Sequential, ExecMode::Pipelined { prefetch: 2 }] {
        for prec in [CommPrecision::F32, CommPrecision::Q8 { block: 64 }] {
            let pm = tiny_plan(exec, prec, DEVICE_MEM_LIMIT);
            let prog = elaborate(&pm);
            let report = run_checks(&pm, &prog);
            assert!(
                report.diagnostics.is_empty(),
                "clean base plan ({} / {}) reported: {}",
                report.exec,
                prec.name(),
                report.diagnostics[0]
            );
            assert!(report.ok());
            assert!(report.collectives_per_rank > 0);
            assert!(report.peak_reserved_bound > 0);
        }
    }
}

/// FS001: one rank's collective payload diverges from rank 0's — the
/// rendezvous barrier would hang. Only the SPMD check may fire (the
/// per-rank protocol walk is order-based and ignores bytes).
#[test]
fn fixture_rank_divergent_schedule_is_fs001() {
    let pm = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);
    let mut prog = elaborate(&pm);
    for e in prog.ranks[1].iter_mut() {
        if let Event::Coll(c) = e {
            c.bytes += 1;
            break;
        }
    }
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::SPMD_DIVERGENCE, "rank-divergent schedule");
    assert!(
        report.diagnostics[0].message.contains("diverges from rank 0"),
        "unexpected FS001 message: {}",
        report.diagnostics[0]
    );
}

/// FS002: a wait on an async-gather handle that was never issued (a
/// stale handle kept across a reshard). Planted identically on every
/// rank so SPMD conformance stays intact.
#[test]
fn fixture_stale_async_handle_is_fs002() {
    let pm = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);
    let mut prog = elaborate(&pm);
    let mut stale = prog.ranks[0]
        .iter()
        .find_map(|e| match e {
            Event::Coll(c) if c.op == CollOp::AllGather => Some(c.clone()),
            _ => None,
        })
        .expect("program elaborates at least one gather");
    stale.phase = Phase::Wait;
    for rank in prog.ranks.iter_mut() {
        rank.push(Event::Coll(stale.clone()));
    }
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::HANDLE_DISCIPLINE, "stale async handle");
    assert!(
        report.diagnostics.iter().any(|d| d.message.contains("never issued")),
        "expected a stale-handle message, got: {}",
        report.diagnostics[0]
    );
}

/// FS003: a transient full buffer whose free was dropped — the ledger
/// replay finds it still claimed at step end. The paired `Reshard`
/// marker stays, so the reshard-pairing check (FS008) must not fire.
#[test]
fn fixture_leaked_full_buffer_is_fs003() {
    let pm = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);
    let mut prog = elaborate(&pm);
    for rank in prog.ranks.iter_mut() {
        let pos = rank
            .iter()
            .position(|e| matches!(e, Event::Free { id: ClaimId::Full(_) }))
            .expect("program frees a full buffer");
        rank.remove(pos);
    }
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::LIFETIME_IMBALANCE, "leaked full buffer");
    assert!(
        report.diagnostics.iter().any(|d| d.message.contains("still claimed at step end")),
        "expected a leak message, got: {}",
        report.diagnostics[0]
    );
}

/// FS008: a bucket gathered but never resharded (unbalanced
/// gather/reshard cycle). The buffer free stays, so the allocator
/// ledger (FS003) must not fire.
#[test]
fn fixture_unbalanced_reshard_is_fs008() {
    let pm = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);
    let mut prog = elaborate(&pm);
    for rank in prog.ranks.iter_mut() {
        let pos = rank
            .iter()
            .position(|e| matches!(e, Event::Reshard { .. }))
            .expect("program reshards");
        rank.remove(pos);
    }
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::RESHARD_UNPAIRED, "unbalanced reshard");
    assert!(
        report.diagnostics.iter().any(|d| d.message.contains("still gathered at step end")),
        "expected an unpaired-reshard message, got: {}",
        report.diagnostics[0]
    );
}

/// FS004: a quant block size that cannot tile the planned shard — a
/// block and its scale would straddle two devices. The layout itself is
/// untouched (FS011 must not fire).
#[test]
fn fixture_misaligned_quant_block_is_fs004() {
    let mut pm = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);
    let s = pm.groups[0].layout.shard_size;
    assert!(s > 0, "tiny embed group shards to a nonzero size");
    // block = shard + 1 divides no shard of this layout
    pm.groups[0].comm_precision = CommPrecision::Q8 { block: (s + 1) as usize };
    let prog = elaborate(&pm);
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::QUANT_MISALIGNED, "misaligned quant block");
}

/// FS005: hierarchical topologies that cannot dispatch — zero pipeline
/// segments, or a host grid that does not span the fsdp mesh.
#[test]
fn fixture_bad_topology_is_fs005() {
    let base = tiny_plan(ExecMode::Sequential, CommPrecision::F32, DEVICE_MEM_LIMIT);

    let mut pm = base.clone();
    pm.topology = Topology { hosts: 2, gpus_per_host: 4, segments: 0 };
    let prog = elaborate(&pm);
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::BAD_TOPOLOGY, "zero-segment topology");

    let mut pm = base;
    pm.topology = Topology { hosts: 2, gpus_per_host: 2, segments: 2 };
    let prog = elaborate(&pm);
    let report = run_checks(&pm, &prog);
    assert_only_code(&report, codes::BAD_TOPOLOGY, "mesh/topology span mismatch");
    assert!(
        report.diagnostics.iter().any(|d| d.message.contains("spans 4 ranks")),
        "expected a span-mismatch message, got: {}",
        report.diagnostics[0]
    );
}

/// FS009: the statically derived footprint cannot fit the device
/// budget — the ledger replay OOMs on the persistent shard claims.
#[test]
fn fixture_over_budget_plan_is_fs009() {
    let preset = presets::by_name("tiny").expect("tiny preset shipped");
    let params = preset.param_table();
    let spec = preset.shard_spec();
    let report = lint(&LintRequest {
        model: "tiny",
        params: &params,
        spec: &spec,
        devices: 8,
        replicas: 1,
        backend: CommBackend::Serial,
        exec: ExecMode::Sequential,
        topology: Topology::flat(),
        hier_threshold: DEFAULT_HIER_THRESHOLD,
        native_layers: None,
        mem_limit: 1, // one byte of device memory
    });
    assert_only_code(&report, codes::PEAK_OVER_LIMIT, "over-budget plan");
}

/// Mesh sizing rule shared with `fsdp-lint --matrix`: smallest
/// power-of-two device count (>= 8) keeping the persistent shard+grad
/// footprint within a quarter of the device budget.
fn matrix_devices(total_params: u64) -> usize {
    let mut devices = 8usize;
    while total_params.saturating_mul(8) / devices as u64 > DEVICE_MEM_LIMIT / 4 {
        devices *= 2;
    }
    devices
}

/// Property: every shipped preset x backend x exec-mode x precision x
/// topology combo lints clean — the static analyzer accepts everything
/// the engine actually ships. Sequential mode is skipped where the full
/// parameters exceed half the device budget (same rule as the CI
/// matrix: the sequential schedule gathers every bucket at once).
#[test]
fn shipped_matrix_lints_clean() {
    let preset_names = [
        "tiny", "small", "llama70b", "gptoss120b", "dsv3_671b", "moe400b", "moe800b",
        "moe1200b", "moe2400b",
    ];
    for name in preset_names {
        let preset = presets::by_name(name).expect("shipped preset");
        let devices = matrix_devices(preset.total_params());
        let seq_fits = preset.total_params().saturating_mul(4) < DEVICE_MEM_LIMIT / 2;
        let params = preset.param_table();
        let topos = [
            Topology::flat(),
            Topology { hosts: devices / 4, gpus_per_host: 4, segments: 2 },
        ];
        for backend in [CommBackend::Serial, CommBackend::Threaded] {
            for prefetch in [0usize, 2] {
                if prefetch == 0 && !seq_fits {
                    continue;
                }
                for prec_name in ["f32", "bf16", "q8"] {
                    let prec = CommPrecision::parse(prec_name).expect("shipped precision");
                    let mut spec = preset.shard_spec();
                    for g in spec.groups.iter_mut() {
                        g.comm_precision = prec;
                    }
                    for topology in topos {
                        let report = lint(&LintRequest {
                            model: name,
                            params: &params,
                            spec: &spec,
                            devices,
                            replicas: 1,
                            backend,
                            exec: ExecMode::from_prefetch(prefetch),
                            topology,
                            hier_threshold: DEFAULT_HIER_THRESHOLD,
                            native_layers: None,
                            mem_limit: DEVICE_MEM_LIMIT,
                        });
                        assert!(
                            report.diagnostics.is_empty(),
                            "{name} devices={devices} backend={} exec={} prec={prec_name} \
                             topo={}: {}",
                            backend.name(),
                            report.exec,
                            report.topology,
                            report
                                .diagnostics
                                .iter()
                                .map(ToString::to_string)
                                .collect::<Vec<_>>()
                                .join("; ")
                        );
                        assert!(
                            report.collectives_per_rank > 0,
                            "{name}: no collectives elaborated"
                        );
                    }
                }
            }
        }
    }
}
