//! Spec-API acceptance tests.
//!
//! 1. **Shim bit-identity** — the legacy `Trainer::{new,with_backend,
//!    with_exec}` constructors are thin shims over `SessionBuilder`; a
//!    hand-declared `ModelSpec` with uniform per-group bindings must
//!    produce trajectories bit-identical to the legacy path across
//!    {serial, threaded} x {sequential, pipelined} x {AdamW, Muon,
//!    Adam8bit}.
//! 2. **Mixed per-group optimizers** — Muon on layer matrices next to
//!    AdamW on embed/head (inexpressible pre-spec) trains end-to-end,
//!    with each group's granularity planned independently, from the Rust
//!    API and from a config file.
//! 3. **Checkpoint round-trips** through the spec API, including
//!    save-at-m / load-at-m' resharding under mixed optimizers.
//! 4. **Per-group schedule/fabric choices** — reshard-after-forward and
//!    fabric selection change comm schedules / timing only, never math.

use std::io::Write;

use vescale_fsdp::checkpoint;
use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::file::ConfigFile;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::spec::{ModelSpec, OptimBinding};
use vescale_fsdp::fsdp::{ExecMode, ShardingPolicy};
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{TrainSession, Trainer};

const TINY_LAYERS: usize = 2;

fn hyper_for(opt: OptimKind) -> AdamHyper {
    match opt {
        OptimKind::Muon => AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
        _ => AdamHyper { lr: 1e-3, ..AdamHyper::default() },
    }
}

fn policy_for(opt: OptimKind) -> ShardingPolicy {
    if opt == OptimKind::Adam8bit {
        ShardingPolicy::uniform_rows(32)
    } else {
        ShardingPolicy::element_wise()
    }
}

type Trajectory = (Vec<f32>, Vec<Vec<f32>>);

fn trajectory(t: &mut TrainSession, steps: usize) -> Trajectory {
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let params = (0..t.engine.params.len()).map(|i| t.engine.read_param(i)).collect();
    (losses, params)
}

fn assert_identical(a: &Trajectory, b: &Trajectory, what: &str) {
    assert_eq!(a.0.len(), b.0.len(), "{what}: step count");
    for (s, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss step {s}: {x} vs {y}");
    }
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        for (x, y) in pa.iter().zip(pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}");
        }
    }
}

/// The declarative counterpart of the legacy constructor: an explicit
/// layerwise `ModelSpec` with the same uniform binding on every group.
fn uniform_spec(opt: OptimKind, policy: &ShardingPolicy) -> ModelSpec {
    let mut spec = ModelSpec::layerwise(TINY_LAYERS);
    for g in spec.groups.iter_mut() {
        g.optim = OptimBinding::from_kind(opt);
        g.policy = policy.clone();
    }
    spec
}

fn run_legacy(opt: OptimKind, m: usize, backend: CommBackend, exec: ExecMode) -> Trajectory {
    let mut t =
        Trainer::with_exec("tiny", m, opt, &policy_for(opt), hyper_for(opt), 42, backend, exec)
            .unwrap();
    trajectory(&mut t, 2)
}

fn run_builder(opt: OptimKind, m: usize, backend: CommBackend, exec: ExecMode) -> Trajectory {
    let mut t = TrainSession::builder("tiny")
        .devices(m)
        .spec(uniform_spec(opt, &policy_for(opt)))
        .hyper(hyper_for(opt))
        .seed(42)
        .backend(backend)
        .exec(exec)
        .build()
        .unwrap();
    trajectory(&mut t, 2)
}

#[test]
fn legacy_shims_bit_identical_to_builder_spec_path() {
    for opt in [OptimKind::AdamW, OptimKind::Muon, OptimKind::Adam8bit] {
        for (backend, exec) in [
            (CommBackend::Serial, ExecMode::Sequential),
            (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
            (CommBackend::Threaded, ExecMode::Sequential),
            (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 1 }),
        ] {
            let legacy = run_legacy(opt, 2, backend, exec);
            let built = run_builder(opt, 2, backend, exec);
            assert_identical(
                &legacy,
                &built,
                &format!("{} {} {}", opt.name(), backend.name(), exec.name()),
            );
        }
    }
}

fn mixed_session(m: usize, backend: CommBackend, exec: ExecMode) -> TrainSession {
    // Muon on layer matrices (with its own lr), AdamW on embed/head —
    // and a per-group granularity only the layer groups use.
    let mut spec = ModelSpec::layerwise_mixed_muon(
        TINY_LAYERS,
        AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
    );
    for g in spec.groups.iter_mut() {
        if g.name.starts_with("layer") {
            g.policy = ShardingPolicy::uniform_rows(4);
        }
    }
    TrainSession::builder("tiny")
        .devices(m)
        .spec(spec)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(7)
        .backend(backend)
        .exec(exec)
        .build()
        .unwrap()
}

#[test]
fn mixed_optimizers_train_end_to_end_with_per_group_planning() {
    let mut t = mixed_session(2, CommBackend::Serial, ExecMode::Sequential);
    // one optimizer per group, bound per the spec
    let names: Vec<&str> = t.optimizers.iter().map(|o| o.name()).collect();
    assert_eq!(names, vec!["adamw", "muon", "muon", "adamw"]);
    assert_eq!(t.engine.buckets[0].name, "embed");
    assert_eq!(t.engine.buckets[3].name, "head");
    // group-local granularity: layer buckets planned with 4-row blocks
    // (4 * d_model = 512 elements), embed/head element-wise
    let d_model = 128u64;
    for b in [1, 2] {
        let spec0 = t.engine.buckets[b].dbuffer.layout.ragged_spec(1);
        assert_eq!(spec0.granularity, 4 * d_model, "layer bucket {b}");
    }
    assert_eq!(t.engine.buckets[0].dbuffer.layout.ragged_spec(0).granularity, 1);
    // trains: loss strictly improves over the first ln(V)-ish value
    let first = t.train_step().unwrap();
    let mut last = first;
    for _ in 0..5 {
        last = t.train_step().unwrap();
    }
    assert!(last.is_finite() && last < first, "loss {first} -> {last}");
    // both optimizer families actually hold state
    assert!(t.optimizers[1].state_bytes() > 0, "muon state");
    assert!(t.optimizers[0].state_bytes() > 0, "adamw state");
}

#[test]
fn mixed_optimizers_bit_identical_across_backends_and_schedules() {
    let reference = {
        let mut t = mixed_session(2, CommBackend::Serial, ExecMode::Sequential);
        trajectory(&mut t, 2)
    };
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 8 }),
    ] {
        let mut t = mixed_session(2, backend, exec);
        let r = trajectory(&mut t, 2);
        assert_identical(
            &reference,
            &r,
            &format!("mixed {} {}", backend.name(), exec.name()),
        );
    }
}

#[test]
fn mixed_config_file_drives_the_builder() {
    let toml = r#"
[model]
preset = "tiny"

[parallel]
fsdp = 2

[run]
optimizer = "adamw"
fabric = "h800"
steps = 2

[group.layers]
optimizer = "muon"
lr = 0.02

[group.head]
granularity = 8
"#;
    let dir = std::env::temp_dir().join("vescale_spec_api_cfg");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("mixed.toml");
    let mut f = std::fs::File::create(&path).unwrap();
    f.write_all(toml.as_bytes()).unwrap();
    drop(f);
    // the same path `vescale-fsdp train --config-file mixed.toml` takes
    let tc = ConfigFile::load(path.to_str().unwrap()).unwrap().train_config().unwrap();
    let mut t = TrainSession::builder(&tc.model)
        .devices(tc.parallel.fsdp)
        .optimizer(OptimBinding::from_kind(tc.optimizer))
        .hyper(AdamHyper { lr: tc.lr as f32, ..AdamHyper::default() })
        .seed(tc.seed)
        .backend(tc.backend)
        .fabric(Fabric::by_name(&tc.fabric).unwrap())
        .overrides(tc.groups.clone())
        .build()
        .unwrap();
    let names: Vec<&str> = t.optimizers.iter().map(|o| o.name()).collect();
    assert_eq!(names, vec!["adamw", "muon", "muon", "adamw"]);
    // the [group.head] granularity reached the planner
    let head = &t.engine.buckets[3];
    assert_eq!(head.dbuffer.layout.ragged_spec(0).granularity, 8);
    let loss = t.train_step().unwrap();
    assert!(loss.is_finite());
    assert_eq!(t.log[0].fabric, "h800");
}

#[test]
fn mixed_checkpoint_reshards_across_mesh_sizes() {
    let dir = std::env::temp_dir().join("vescale_spec_api_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let mut src = mixed_session(4, CommBackend::Serial, ExecMode::Sequential);
    for _ in 0..2 {
        src.train_step().unwrap();
    }
    checkpoint::save(&src.engine, &dir).unwrap();
    let meta = checkpoint::read_meta(&dir).unwrap();
    assert_eq!(meta.mesh, 4);
    assert_eq!(meta.groups, vec!["embed", "layer0", "layer1", "head"]);
    // load at a different mesh size (save-at-4 / load-at-2 resharding)
    let mut dst = mixed_session(2, CommBackend::Serial, ExecMode::Sequential);
    checkpoint::load(&mut dst.engine, &dir).unwrap();
    for i in 0..src.engine.params.len() {
        let a = src.engine.read_param(i);
        let b = dst.engine.read_param(i);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "param {i} resharded");
        }
    }
    // the restored session keeps training under its mixed bindings
    let loss = dst.train_step().unwrap();
    assert!(loss.is_finite());
}

#[test]
fn keep_unsharded_group_skips_backward_regather_same_math() {
    let build = |keep_head: bool| {
        let mut spec = ModelSpec::layerwise(TINY_LAYERS);
        if keep_head {
            spec.group_named_mut("head").unwrap().reshard_after_forward = false;
        }
        TrainSession::builder("tiny")
            .devices(2)
            .spec(spec)
            .seed(11)
            .exec(ExecMode::Pipelined { prefetch: 2 })
            .build()
            .unwrap()
    };
    let mut reshard = build(false);
    let mut keep = build(true);
    let a = trajectory(&mut reshard, 2);
    let b = trajectory(&mut keep, 2);
    assert_identical(&a, &b, "reshard toggle must not change math");
    // 4 buckets: resharding path re-gathers all 4 in backward (8 AG/step),
    // keeping the head live saves exactly one AllGather per step
    let ag_reshard = reshard.engine.stats().count("all_gather");
    let ag_keep = keep.engine.stats().count("all_gather");
    assert_eq!(ag_reshard, 2 * 8, "baseline schedule");
    assert_eq!(ag_keep, 2 * 7, "one backward re-gather skipped per step");
}

#[test]
fn fabric_choice_changes_timing_not_math() {
    let run = |fabric: Fabric| {
        let mut t = TrainSession::builder("tiny")
            .devices(2)
            .seed(3)
            .fabric(fabric)
            .build()
            .unwrap();
        let traj = trajectory(&mut t, 2);
        let sim = t.engine.comm.sim_time();
        let fabric_name = t.log[0].fabric;
        (traj, sim, fabric_name)
    };
    let (a, sim_h800, name_h800) = run(Fabric::h800());
    let (b, sim_a100, name_a100) = run(Fabric::a100());
    assert_identical(&a, &b, "fabric is a timing model only");
    assert_eq!(name_h800, "h800");
    assert_eq!(name_a100, "a100");
    assert!(
        sim_a100 > sim_h800,
        "a100 must be modeled slower: {sim_a100} vs {sim_h800}"
    );
}
