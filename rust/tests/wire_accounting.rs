//! Wire-byte accounting regression: the StepLog CSV's wire columns
//! (payload / scale / pad) are produced by exactly one pipeline stage —
//! `CollectiveLaunch::comm_record` — and must stay bit-for-bit what the
//! seed produced. In the sequential tiny schedule every bucket ships one
//! parameter AllGather and one gradient ReduceScatter of `shard_size`
//! elements per rank per step, each accounted as
//! `CommPrecision::wire_volume(shard_size)` times the group size (plus
//! the dense cross-replica AllReduce under HSDP). The goldens below are
//! computed from the quant math alone, independent of the comm path.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::train::{save_log, TrainSession};

const PRECISIONS: [CommPrecision; 3] = [
    CommPrecision::F32,
    CommPrecision::Bf16,
    CommPrecision::Q8 { block: 64 },
];

fn run(
    prec: CommPrecision,
    backend: CommBackend,
    exec: ExecMode,
    replicas: usize,
    steps: usize,
) -> TrainSession {
    let mut t = TrainSession::builder("tiny")
        .devices(2)
        .replicas(replicas)
        .optimizer(OptimBinding::AdamW)
        .seed(42)
        .backend(backend)
        .exec(exec)
        .comm_precision(prec)
        .build()
        .unwrap();
    for _ in 0..steps {
        t.train_step().unwrap();
    }
    t
}

/// Analytic per-step wire columns of the sequential schedule: one
/// AllGather plus one ReduceScatter per bucket, each of `shard_size`
/// elems per rank across the fsdp group, plus the dense f32
/// cross-replica AllReduce of the reduced shard when `replicas > 1`.
fn golden_step_wire(t: &TrainSession, prec: CommPrecision, replicas: u64) -> (u64, u64, u64) {
    let (mut payload, mut scale, mut pad) = (0u64, 0u64, 0u64);
    for b in &t.engine.buckets {
        let m = b.dbuffer.layout.num_devices as u64;
        let vol = prec.wire_volume(b.dbuffer.layout.shard_size);
        payload += 2 * m * vol.payload;
        scale += 2 * m * vol.scale;
        pad += 2 * m * vol.pad;
        if replicas > 1 {
            payload += replicas * b.dbuffer.layout.shard_size * 4;
        }
    }
    (payload, scale, pad)
}

fn step_wire(t: &TrainSession) -> Vec<(u64, u64, u64)> {
    t.log.iter().map(|l| (l.wire_payload, l.wire_scale, l.wire_pad)).collect()
}

#[test]
fn steplog_wire_columns_match_quant_math_for_every_precision() {
    for prec in PRECISIONS {
        let t = run(prec, CommBackend::Serial, ExecMode::Sequential, 1, 3);
        let want = golden_step_wire(&t, prec, 1);
        let stats = t.engine.stats();
        let buckets = t.engine.buckets.len();
        assert_eq!(buckets, 4, "tiny = embed|layer0|layer1|head");
        assert_eq!(stats.count("all_gather"), buckets * 3, "{} AG count", prec.name());
        assert_eq!(stats.count("reduce_scatter"), buckets * 3, "{} RS count", prec.name());
        assert_eq!(stats.count("all_reduce"), 0, "{}: flat run must not AR", prec.name());
        assert_eq!(t.log.len(), 3);
        for l in &t.log {
            assert_eq!(
                (l.wire_payload, l.wire_scale, l.wire_pad),
                want,
                "{} step {}",
                prec.name(),
                l.step
            );
        }
    }
}

#[test]
fn hsdp_replica_allreduce_accounted_dense() {
    let t = run(CommPrecision::F32, CommBackend::Serial, ExecMode::Sequential, 2, 2);
    let buckets = t.engine.buckets.len();
    assert_eq!(t.engine.stats().count("all_reduce"), buckets * 2);
    let want = golden_step_wire(&t, CommPrecision::F32, 2);
    for l in &t.log {
        assert_eq!((l.wire_payload, l.wire_scale, l.wire_pad), want, "hsdp step {}", l.step);
    }
}

#[test]
fn wire_columns_invariant_across_backends_and_schedules() {
    // the columns are descriptor-derived, so neither the backend nor the
    // overlap schedule may move them; pipelined steps re-gather in
    // backward, so both modes must at least ship the sequential volume
    // and stay steady step over step
    for prec in PRECISIONS {
        let seq = step_wire(&run(prec, CommBackend::Serial, ExecMode::Sequential, 1, 2));
        let thr = step_wire(&run(prec, CommBackend::Threaded, ExecMode::Sequential, 1, 2));
        assert_eq!(seq, thr, "{}: threaded sequential diverges", prec.name());
        for (backend, what) in
            [(CommBackend::Serial, "serial"), (CommBackend::Threaded, "threaded")]
        {
            let pip = step_wire(&run(prec, backend, ExecMode::Pipelined { prefetch: 2 }, 1, 2));
            assert_eq!(pip[0], pip[1], "{} {} pipelined not steady", prec.name(), what);
            assert!(
                pip[0].0 >= seq[0].0,
                "{} {} pipelined ships less payload than sequential",
                prec.name(),
                what
            );
        }
    }
}

#[test]
fn csv_wire_columns_regress_to_golden() {
    let prec = CommPrecision::Q8 { block: 64 };
    let t = run(prec, CommBackend::Serial, ExecMode::Sequential, 1, 2);
    let want = golden_step_wire(&t, prec, 1);
    let path = save_log("test_wire_accounting", &t.log).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    let header = csv.lines().next().unwrap();
    assert!(header.contains("wire_payload,wire_scale,wire_pad"), "{header}");
    for row in csv.lines().skip(1) {
        let cols: Vec<&str> = row.split(',').collect();
        let n = cols.len();
        let got: (u64, u64, u64) = (
            cols[n - 5].parse().unwrap(),
            cols[n - 4].parse().unwrap(),
            cols[n - 3].parse().unwrap(),
        );
        assert_eq!(got, want, "CSV row {row}");
    }
    let _ = std::fs::remove_file(path);
}
