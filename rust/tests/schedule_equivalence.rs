//! Schedule equivalence: the bucket-pipelined overlap executor must
//! produce **bit-identical** trajectories to the sequential step loop —
//! across cluster backends {serial, threaded}, prefetch depths, and
//! optimizers {AdamW, Muon, Adam8bit} — plus the HSDP reduction path and
//! the prefetch-bounded memory claim.

use vescale_fsdp::cluster::{CommBackend, CommBuilder};
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::{exec, ExecMode, FsdpEngine, ShardingPolicy};
use vescale_fsdp::mesh::DeviceMesh;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::runtime::{Engine as Runtime, ModelCfg};
use vescale_fsdp::train::{init_full_params, Corpus, Trainer};

// ---- harness over a custom many-layer micro config ----------------------
// 8 layers of d=16 make 10 buckets with trivial compute, so deep prefetch
// windows and per-bucket memory lifecycles are exercised cheaply.

fn micro_runtime() -> (Runtime, ModelCfg) {
    let mut runtime = Runtime::load_default().unwrap();
    let cfg = ModelCfg::with_abi(64, 16, 8, 2, 32, 8, 2);
    runtime.manifest.configs.insert("micro8".to_string(), cfg.clone());
    (runtime, cfg)
}

fn layer_groups(cfg: &ModelCfg) -> Vec<usize> {
    cfg.params
        .iter()
        .map(|(name, _)| {
            if name.starts_with("embed") {
                0
            } else if let Some(rest) = name.strip_prefix("layers.") {
                1 + rest.split('.').next().unwrap().parse::<usize>().unwrap()
            } else {
                1 + cfg.n_layers
            }
        })
        .collect()
}

struct MicroRun {
    losses: Vec<f32>,
    grad_shards: Vec<Vec<Vec<f32>>>,
    param_shards: Vec<Vec<Vec<f32>>>,
    all_reduce_count: usize,
    peak_allocated: u64,
}

/// Run `steps` micro8 steps under one (mesh, backend, mode) combination,
/// with a plain SGD fold-in between steps so trajectories compound.
fn run_micro(mesh: DeviceMesh, backend: CommBackend, mode: ExecMode, steps: usize) -> MicroRun {
    let (mut runtime, cfg) = micro_runtime();
    let groups = layer_groups(&cfg);
    let mut engine = FsdpEngine::new_with_comm(
        cfg.params.clone(),
        &groups,
        mesh,
        &ShardingPolicy::element_wise(),
        Fabric::h800(),
        CommBuilder::new(backend).build(),
    )
    .unwrap();
    engine.init_params(&init_full_params(&cfg.params, 5)).unwrap();
    let m = engine.num_devices();
    let mut corpus = Corpus::new(cfg.vocab, 9);
    let mut losses = Vec::new();
    for _ in 0..steps {
        let batches: Vec<_> = (0..m).map(|_| corpus.batch(cfg.batch, cfg.seq)).collect();
        let out = exec::run_step(&mut engine, &mut runtime, "micro8", &batches, mode).unwrap();
        losses.extend(out.losses);
        for b in engine.buckets.iter_mut() {
            let grads = b.grad_shards.clone();
            for (shard, g) in b.dbuffer.shards.iter_mut().zip(&grads) {
                for (p, &gv) in shard.iter_mut().zip(g) {
                    *p -= 0.1 * gv;
                }
            }
        }
    }
    let (_, peak_allocated) = engine.memory_stats();
    MicroRun {
        losses,
        grad_shards: engine.buckets.iter().map(|b| b.grad_shards.clone()).collect(),
        param_shards: engine.buckets.iter().map(|b| b.dbuffer.shards.clone()).collect(),
        all_reduce_count: engine.stats().count("all_reduce"),
        peak_allocated,
    }
}

fn assert_runs_equal(a: &MicroRun, b: &MicroRun, what: &str) {
    assert_eq!(a.losses.len(), b.losses.len(), "{what}: loss count");
    for (i, (x, y)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss {i}: {x} vs {y}");
    }
    for (bi, (ga, gb)) in a.grad_shards.iter().zip(&b.grad_shards).enumerate() {
        for (x, y) in ga.iter().flatten().zip(gb.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: bucket {bi} grads differ");
        }
    }
    for (pa, pb) in a.param_shards.iter().zip(&b.param_shards) {
        for (x, y) in pa.iter().flatten().zip(pb.iter().flatten()) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: params differ");
        }
    }
}

#[test]
fn micro_all_schedules_bit_identical() {
    let reference = run_micro(
        DeviceMesh::flat("fsdp", 4),
        CommBackend::Serial,
        ExecMode::Sequential,
        3,
    );
    for backend in [CommBackend::Serial, CommBackend::Threaded] {
        for prefetch in [1usize, 2, 8] {
            let r = run_micro(
                DeviceMesh::flat("fsdp", 4),
                backend,
                ExecMode::Pipelined { prefetch },
                3,
            );
            assert_runs_equal(
                &reference,
                &r,
                &format!("{} pipelined{prefetch}", backend.name()),
            );
        }
    }
    let thr_seq = run_micro(
        DeviceMesh::flat("fsdp", 4),
        CommBackend::Threaded,
        ExecMode::Sequential,
        3,
    );
    assert_runs_equal(&reference, &thr_seq, "threaded sequential");
}

#[test]
fn hsdp_schedules_bit_identical_and_account_allreduce() {
    let mesh = || DeviceMesh::new(&[("replica", 2), ("fsdp", 2)]).unwrap();
    let reference = run_micro(mesh(), CommBackend::Serial, ExecMode::Sequential, 2);
    // 10 buckets x 2 steps, each reduction runs the cross-replica AR
    assert_eq!(reference.all_reduce_count, 20, "HSDP AllReduce not accounted");
    for (backend, mode) in [
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 1 }),
    ] {
        let r = run_micro(mesh(), backend, mode, 2);
        assert_runs_equal(&reference, &r, &format!("hsdp {} {}", backend.name(), mode.name()));
        assert_eq!(r.all_reduce_count, 20);
    }
}

#[test]
fn prefetch_caps_live_memory() {
    // sequential keeps all 10 full buckets live; pipelined-1 keeps at
    // most 2 (plus bounded ReduceScatter staging) — the allocator must
    // *measure* that difference
    let seq = run_micro(
        DeviceMesh::flat("fsdp", 2),
        CommBackend::Serial,
        ExecMode::Sequential,
        1,
    );
    let pip1 = run_micro(
        DeviceMesh::flat("fsdp", 2),
        CommBackend::Serial,
        ExecMode::Pipelined { prefetch: 1 },
        1,
    );
    let pip8 = run_micro(
        DeviceMesh::flat("fsdp", 2),
        CommBackend::Serial,
        ExecMode::Pipelined { prefetch: 8 },
        1,
    );
    assert!(
        pip1.peak_allocated < seq.peak_allocated,
        "pipelined-1 peak {} !< sequential peak {}",
        pip1.peak_allocated,
        seq.peak_allocated
    );
    assert!(
        pip1.peak_allocated <= pip8.peak_allocated,
        "deeper prefetch cannot shrink the window: {} vs {}",
        pip1.peak_allocated,
        pip8.peak_allocated
    );
}

// ---- full-trainer trajectories (real optimizers) ------------------------

fn run_trainer(
    opt: OptimKind,
    m: usize,
    backend: CommBackend,
    exec: ExecMode,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let hyper = match opt {
        OptimKind::Muon => AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
        _ => AdamHyper { lr: 1e-3, ..AdamHyper::default() },
    };
    let policy = if opt == OptimKind::Adam8bit {
        ShardingPolicy::uniform_rows(32)
    } else {
        ShardingPolicy::element_wise()
    };
    let mut t = Trainer::with_exec("tiny", m, opt, &policy, hyper, 42, backend, exec).unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let params = (0..t.engine.params.len())
        .map(|i| t.engine.read_param(i))
        .collect();
    (losses, params)
}

fn assert_trajectories_equal(
    a: &(Vec<f32>, Vec<Vec<f32>>),
    b: &(Vec<f32>, Vec<Vec<f32>>),
    what: &str,
) {
    for (step, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: step {step}: {x} vs {y}");
    }
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        for (x, y) in pa.iter().zip(pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}");
        }
    }
}

#[test]
fn adamw_trainer_pipelined_matches_sequential() {
    let reference = run_trainer(
        OptimKind::AdamW,
        4,
        CommBackend::Serial,
        ExecMode::Sequential,
        2,
    );
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 1 }),
    ] {
        let r = run_trainer(OptimKind::AdamW, 4, backend, exec, 2);
        assert_trajectories_equal(
            &reference,
            &r,
            &format!("adamw {} {}", backend.name(), exec.name()),
        );
    }
}

#[test]
fn muon_trainer_pipelined_matches_sequential() {
    let reference = run_trainer(
        OptimKind::Muon,
        2,
        CommBackend::Serial,
        ExecMode::Sequential,
        2,
    );
    let r = run_trainer(
        OptimKind::Muon,
        2,
        CommBackend::Threaded,
        ExecMode::Pipelined { prefetch: 2 },
        2,
    );
    assert_trajectories_equal(&reference, &r, "muon threaded pipelined2");
}

#[test]
fn adam8bit_trainer_pipelined_matches_sequential() {
    let reference = run_trainer(
        OptimKind::Adam8bit,
        2,
        CommBackend::Serial,
        ExecMode::Sequential,
        2,
    );
    let r = run_trainer(
        OptimKind::Adam8bit,
        2,
        CommBackend::Threaded,
        ExecMode::Pipelined { prefetch: 8 },
        2,
    );
    assert_trajectories_equal(&reference, &r, "adam8bit threaded pipelined8");
}

#[test]
fn executor_reports_measured_timeline() {
    let mut t = Trainer::with_exec(
        "tiny",
        2,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        AdamHyper::default(),
        3,
        CommBackend::Threaded,
        ExecMode::Pipelined { prefetch: 2 },
    )
    .unwrap();
    t.train_step().unwrap();
    let r = t.last_report.as_ref().expect("report");
    assert!(r.wall_s > 0.0);
    assert!(r.exposed_comm_s >= 0.0 && r.exposed_comm_s <= r.wall_s * 1.5);
    assert!(r.sim_comm_s > 0.0, "fabric comm must be recorded");
    assert!(r.peak_reserved >= r.peak_allocated);
    assert!(r.peak_allocated > 0);
    assert!(t.log[0].exposed_s >= 0.0);
}
