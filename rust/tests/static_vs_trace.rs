//! Static/dynamic cross-validation: the analyzer's symbolic elaboration
//! (`SessionBuilder::analyze`) predicts, before any thread spawns, the
//! exact per-(span, phase) collective subsequences the tracer will
//! record on a live run — and an allocator peak that upper-bounds the
//! measured one. Runs the `tiny` config for two steps across
//! {serial, threaded} x {sequential, pipelined} x {flat, 2x4:2} and
//! compares span-for-span.

use vescale_fsdp::analysis::AnalysisReport;
use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::trace::TraceLevel;
use vescale_fsdp::train::TrainSession;

// Compile-time proof that the analyzer's collective vocabulary IS the
// runtime's launch vocabulary (not a parallel copy that could drift):
// `analysis::ir::CollOp` must unify with `cluster::LaunchOp` as a type.
const _: fn(vescale_fsdp::analysis::ir::CollOp) -> vescale_fsdp::cluster::LaunchOp = |op| op;

/// Every (name, phase) lane a logical collective span can occupy.
const LANES: [(&str, &str); 6] = [
    ("ag", "sync"),
    ("rs", "sync"),
    ("ag", "issue"),
    ("ag", "wait"),
    ("rs", "issue"),
    ("rs", "wait"),
];

/// The traced `ag`/`rs` spans of each step must match the static
/// prediction: same count, and per (name, phase) the identical
/// (bucket, bytes) sequence.
fn assert_sequences(
    report: &AnalysisReport,
    traced: &[(u64, String, String, String, u64)],
    label: &str,
) {
    let mut steps: Vec<u64> = traced.iter().map(|s| s.0).collect();
    steps.dedup();
    assert_eq!(steps.len(), 2, "{label}: expected spans from 2 steps, got {steps:?}");
    for &step in &steps {
        let spans: Vec<_> = traced.iter().filter(|s| s.0 == step).collect();
        assert_eq!(
            spans.len(),
            report.expected_spans.len(),
            "{label} step {step}: traced {} collective spans, static predicts {}",
            spans.len(),
            report.expected_spans.len()
        );
        for (name, phase) in LANES {
            let expected = report.expected_subsequence(name, phase);
            let got: Vec<(String, u64)> = spans
                .iter()
                .filter(|s| s.1 == name && s.3 == phase)
                .map(|s| (s.2.clone(), s.4))
                .collect();
            assert_eq!(
                got, expected,
                "{label} step {step}: {name}/{phase} (bucket, bytes) sequence diverges \
                 from the static prediction"
            );
        }
    }
}

#[test]
fn static_schedule_matches_traced_run() {
    let hier = Topology { hosts: 2, gpus_per_host: 4, segments: 2 };
    for backend in [CommBackend::Serial, CommBackend::Threaded] {
        for exec in [ExecMode::Sequential, ExecMode::Pipelined { prefetch: 2 }] {
            for topology in [None, Some(hier)] {
                let label = format!(
                    "tiny backend={} exec={} topo={}",
                    backend.name(),
                    exec.name(),
                    topology.map_or("flat".to_string(), |t| t.label())
                );
                let mut builder = TrainSession::builder("tiny")
                    .devices(8)
                    .seed(7)
                    .backend(backend)
                    .exec(exec)
                    .trace(TraceLevel::Comm);
                if let Some(t) = topology {
                    builder = builder.fabric(Fabric::h800().with_topology(t));
                }

                // static pre-flight on the exact session configuration
                let report = builder.analyze().unwrap_or_else(|e| {
                    panic!("{label}: analyze failed: {e:#}");
                });
                assert!(
                    report.diagnostics.is_empty(),
                    "{label}: shipped config must lint clean, got: {}",
                    report.diagnostics[0]
                );
                assert!(!report.expected_spans.is_empty(), "{label}: empty prediction");

                // live run on the same builder
                let mut session = builder.build().unwrap();
                session.run(2).unwrap();

                assert_sequences(&report, &session.tracer.collective_sequence(), &label);

                // the statically derived peak bounds the measured one
                let last = session.log.last().expect("two steps logged");
                assert!(last.peak_reserved > 0, "{label}: no allocator activity");
                assert!(
                    last.peak_reserved <= report.peak_reserved_bound,
                    "{label}: measured peak reserved {} exceeds static bound {}",
                    last.peak_reserved,
                    report.peak_reserved_bound
                );
                assert!(
                    last.peak_allocated <= report.peak_allocated_bound,
                    "{label}: measured peak allocated {} exceeds static bound {}",
                    last.peak_allocated,
                    report.peak_allocated_bound
                );
            }
        }
    }
}
