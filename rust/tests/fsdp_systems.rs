//! Cross-system integration tests over the symbolic engine: the paper's
//! qualitative claims must hold across presets and scales (who wins, by
//! roughly what factor, where the pathologies appear).

use vescale_fsdp::baselines;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec, StepReport, SystemBehavior};

fn run(
    preset: &presets::ModelPreset,
    sys: &SystemBehavior,
    parallel: ParallelConfig,
    tokens: u64,
) -> StepReport {
    simulate_step(
        preset,
        &parallel,
        OptimKind::AdamW,
        tokens,
        &Fabric::h800(),
        &GpuSpec::h800(),
        sys,
    )
    .unwrap()
}

#[test]
fn vescale_wins_throughput_on_all_models_at_128() {
    // 800B needs >= ~200 GPUs just for fp32 master + Adam states; the
    // paper runs it at 1K+ (§6.2), so that preset is tested at 1024.
    let cases = [
        (presets::llama70b(), 128usize),
        (presets::gptoss120b(), 128),
        (presets::moe_internal(800.0), 1024),
    ];
    for (preset, m) in cases {
        let tokens = preset.seq_default as u64;
        let ve = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(m), tokens);
        assert!(!ve.oom, "{} OOM at {m}", preset.name);
        for b in baselines::all_baselines() {
            let r = run(&preset, &b, ParallelConfig::fsdp_only(m), tokens);
            assert!(
                ve.tokens_per_sec >= r.tokens_per_sec * 0.999,
                "{}: veScale {} < {} {}",
                preset.name,
                ve.tokens_per_sec,
                b.name,
                r.tokens_per_sec
            );
        }
    }
}

#[test]
fn vescale_memory_lowest_on_all_models() {
    for preset in [presets::llama70b(), presets::gptoss120b()] {
        let tokens = preset.seq_default as u64;
        let ve = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(128), tokens);
        for b in baselines::all_baselines() {
            let r = run(&preset, &b, ParallelConfig::fsdp_only(128), tokens);
            assert!(
                ve.peak_reserved <= r.peak_reserved,
                "{}: veScale {} > {} {}",
                preset.name,
                ve.peak_reserved,
                b.name,
                r.peak_reserved
            );
        }
    }
}

#[test]
fn memory_saving_in_paper_band() {
    // paper: 16-30% lower peak memory than existing systems (vs the
    // worst-of-baselines on each model, the headline comparison)
    let preset = presets::gptoss120b();
    let tokens = preset.seq_default as u64;
    let ve = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(128), tokens);
    let worst = baselines::all_baselines()
        .iter()
        .map(|b| run(&preset, b, ParallelConfig::fsdp_only(128), tokens).peak_reserved)
        .max()
        .unwrap();
    let saving = 1.0 - ve.peak_reserved as f64 / worst as f64;
    assert!(saving > 0.10, "saving only {saving:.2}");
}

#[test]
fn throughput_margin_in_paper_band_moe() {
    // paper: 11-66% faster on MoE models
    let preset = presets::gptoss120b();
    let tokens = preset.seq_default as u64;
    let ve = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(128), tokens);
    let worst_base = baselines::all_baselines()
        .iter()
        .map(|b| run(&preset, b, ParallelConfig::fsdp_only(128), tokens).tokens_per_sec)
        .fold(f64::MAX, f64::min);
    let margin = ve.tokens_per_sec / worst_base;
    assert!(margin > 1.10, "MoE margin only {margin:.3}");
}

#[test]
fn hsdp_memory_grows_marginally_with_replication() {
    // paper §6.1: memory decreases with FSDP size, grows only marginally
    // with replication factor
    let preset = presets::llama70b();
    let f256 = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(256), 4096);
    let h2 = run(
        &preset,
        &baselines::vescale(1),
        ParallelConfig { fsdp: 256, replicas: 2, ep: 1 },
        4096,
    );
    let f128 = run(&preset, &baselines::vescale(1), ParallelConfig::fsdp_only(128), 4096);
    assert!(f256.peak_reserved < f128.peak_reserved);
    let growth = h2.peak_reserved as f64 / f256.peak_reserved as f64;
    assert!(growth < 1.1, "replication inflated memory {growth:.2}x");
}

#[test]
fn weak_scaling_near_linear_to_8k() {
    let preset = presets::moe_internal(800.0);
    let ve = baselines::vescale(1);
    let base = run(&preset, &ve, ParallelConfig::fsdp_only(1024), 8192);
    for m in [2048, 4096, 8192] {
        let r = run(&preset, &ve, ParallelConfig::fsdp_only(m), 8192);
        let eff = (r.tokens_per_sec / base.tokens_per_sec)
            / (m as f64 / 1024.0);
        assert!(eff > 0.85, "weak-scaling efficiency {eff:.2} at m={m}");
    }
}

#[test]
fn strong_scaling_sublinear_when_tokens_shrink() {
    // fixed global batch; the paper tunes EP per setting ("we adopt
    // cross-node Expert Parallelism, which further reduces FSDP
    // communication time"). With EP=8, 1K GPUs are compute-bound; at 8K
    // the shrunken per-device batch exposes comm — a 3-4x gain, not 8x
    // (paper: 3.4x from 1K to 8K at a 16M-token batch).
    let preset = presets::moe_internal(800.0);
    let ve = baselines::vescale(1);
    let global_tokens = 16_000_000u64;
    let t1k = run(
        &preset,
        &ve,
        ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 },
        global_tokens / 1024,
    );
    let t8k = run(
        &preset,
        &ve,
        ParallelConfig { fsdp: 8192, replicas: 1, ep: 8 },
        global_tokens / 8192,
    );
    let speedup = t8k.tokens_per_sec / t1k.tokens_per_sec;
    assert!(speedup > 1.5, "some strong scaling expected: {speedup:.2}");
    assert!(speedup < 7.9, "perfect scaling is implausible: {speedup:.2}");
}

#[test]
fn planner_quality_padding_bands() {
    // Fig 11: 1x/16x row granularity keep padding < 3% across FSDP sizes
    use vescale_fsdp::planner::{plan, TensorDecl};
    for preset in [presets::dsv3_671b(), presets::gptoss120b()] {
        for m in [8usize, 32, 128] {
            for rows in [1u64, 16] {
                // quantize only FFN/expert weights (the paper's scheme)
                let decls: Vec<TensorDecl> = preset
                    .all_params()
                    .iter()
                    .map(|p| {
                        // "row" = one row of the innermost expert matrix
                        // (last dim), not a whole dim-0 slice of a fused
                        // expert tensor
                        let row = *p.shape.last().unwrap() as u64;
                        let g = if p.name.contains("expert") || p.name.contains("mlp") {
                            (rows * row).min(p.numel())
                        } else {
                            1
                        };
                        TensorDecl::new(&p.name, p.numel(), g.max(1))
                    })
                    .collect();
                let layout = plan(&decls, m, 4).unwrap();
                assert!(
                    layout.padding_ratio() < 0.03,
                    "{} m={m} rows={rows}: padding {:.4}",
                    preset.name,
                    layout.padding_ratio()
                );
            }
        }
    }
}

#[test]
fn sgd_fallback_fits_where_adamw_tight() {
    // paper: SGD used to avoid OOM on GPT-OSS for the baselines
    let preset = presets::gptoss120b();
    let b = baselines::fsdp1();
    let adamw = simulate_step(
        &preset,
        &ParallelConfig::fsdp_only(128),
        OptimKind::AdamW,
        8192,
        &Fabric::h800(),
        &GpuSpec::h800(),
        &b,
    )
    .unwrap();
    let sgd = simulate_step(
        &preset,
        &ParallelConfig::fsdp_only(128),
        OptimKind::Sgd,
        8192,
        &Fabric::h800(),
        &GpuSpec::h800(),
        &b,
    )
    .unwrap();
    assert!(sgd.peak_reserved < adamw.peak_reserved);
}

#[test]
fn mfu_improves_with_model_size_at_1k() {
    // Fig 9d: MFU slightly improves as models grow on 1K GPUs
    let ve = baselines::vescale(1);
    let small = run(&presets::moe_internal(400.0), &ve, ParallelConfig::fsdp_only(1024), 8192);
    let big = run(&presets::moe_internal(2400.0), &ve, ParallelConfig { fsdp: 1024, replicas: 1, ep: 8 }, 8192);
    assert!(!big.oom, "2.4T must train on 1K GPUs (the paper's claim)");
    assert!(big.mfu >= small.mfu * 0.9, "MFU collapsed: {} vs {}", big.mfu, small.mfu);
}
