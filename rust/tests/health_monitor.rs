//! Health-monitor validity: monitoring must be *pure* — loss
//! trajectories with the monitor armed are bit-identical to monitor-off
//! runs on every backend/executor combination — while an armed monitor
//! actually observes the run: per-step metric series fill, the
//! collective watchdog names a deterministically injected straggler
//! ([FS204] with rank, collective, and bucket), and the postmortem
//! document round-trips as valid `fsdp-postmortem-v1` JSON.

use vescale_fsdp::analysis::diag::codes;
use vescale_fsdp::cluster::{set_arrival_stagger, CommBackend, Communicator, ThreadedComm};
use vescale_fsdp::comm::Topology;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::obs::{ObsConfig, Observer};
use vescale_fsdp::trace::Tracer;
use vescale_fsdp::train::TrainSession;
use vescale_fsdp::util::json::Json;

fn session(backend: CommBackend, exec: ExecMode, monitor: bool) -> TrainSession {
    let mut b = TrainSession::builder("tiny")
        .devices(2)
        .seed(11)
        .backend(backend)
        .exec(exec);
    if monitor {
        // large deadline: the watchdog is armed but must stay quiet
        b = b.watchdog_ms(60_000);
    }
    b.build().unwrap()
}

fn losses(s: &TrainSession) -> Vec<u32> {
    s.log.iter().map(|l| l.loss.to_bits()).collect()
}

#[test]
fn monitoring_is_bitwise_invisible() {
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Sequential),
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 2 }),
    ] {
        let mut off = session(backend, exec, false);
        off.run(2).unwrap();
        let mut on = session(backend, exec, true);
        on.run(2).unwrap();
        assert!(!off.obs.armed(), "unmonitored session must stay disarmed");
        assert!(on.obs.armed());
        assert_eq!(
            losses(&off),
            losses(&on),
            "{} {}: monitoring perturbed the losses",
            backend.name(),
            exec.name()
        );
        // the armed monitor really observed the run
        let m = on.obs.metrics().unwrap();
        let series_names =
            ["step_time_s", "exposed_comm_s", "overlap_efficiency", "wire_bytes", "rank_skew_s"];
        for series in series_names {
            assert_eq!(
                m.series(series).len(),
                2,
                "{} {}: series {series} incomplete",
                backend.name(),
                exec.name()
            );
        }
        assert!(
            !on.obs.watchdog_fired(),
            "{} {}: spurious watchdog fire on a healthy run",
            backend.name(),
            exec.name()
        );
        on.obs.shutdown();
    }
}

#[test]
fn armed_session_exports_metrics_snapshots() {
    let mut s = session(CommBackend::Threaded, ExecMode::Pipelined { prefetch: 2 }, true);
    s.run(2).unwrap();
    let m = s.obs.metrics().unwrap();
    let prom = m.prometheus();
    for want in ["fsdp_step_time_s", "fsdp_wire_bytes_total", "fsdp_mem_peak_reserved"] {
        assert!(prom.contains(want), "prometheus snapshot missing {want}:\n{prom}");
    }
    let j = m.json();
    assert_eq!(j.get("schema").and_then(Json::as_str), Some("fsdp-metrics-v1"));
    // snapshot survives a text round-trip (what fsdp-report reads)
    let parsed = Json::parse(&j.to_string()).unwrap();
    let steps = parsed
        .get("series")
        .and_then(|s| s.get("step_time_s"))
        .and_then(|s| s.get("values"))
        .and_then(Json::as_arr)
        .unwrap();
    assert_eq!(steps.len(), 2);
    s.obs.shutdown();
}

#[test]
fn staggered_stall_trips_watchdog_and_postmortem() {
    let obs = Observer::new(
        ObsConfig { watchdog_ms: 30, ring_capacity: 32, ..ObsConfig::default() },
        4,
    );
    let comm = ThreadedComm::with_obs(Tracer::off(), Topology::flat(), obs.clone());
    obs.set_step(1);
    obs.set_phase("gather");
    obs.set_bucket("embed");

    // big enough for the rendezvous path (m*m*s >= the serial-fallback
    // threshold), so rank threads really meet at a barrier
    let (m, s) = (4usize, 16 * 1024usize);
    let mut bufs: Vec<Vec<f32>> = (0..m)
        .map(|r| {
            let mut b = vec![0.0f32; m * s];
            for (i, x) in b[r * s..(r + 1) * s].iter_mut().enumerate() {
                *x = (r * s + i) as f32;
            }
            b
        })
        .collect();
    let mut expected = bufs.clone();
    vescale_fsdp::comm::all_gather(&mut expected, s).unwrap();

    // rank 0 (the caller's thread) arrives 120 ms late: ranks 1..3 dwell
    // in the rendezvous past the 30 ms deadline, and the exit-path
    // deadline check reports them no matter how the threads schedule
    set_arrival_stagger(&[120_000]);
    let result = comm.all_gather(&mut bufs, s);
    set_arrival_stagger(&[]);
    result.unwrap();

    assert_eq!(bufs, expected, "injected stagger changed the collective's result");
    assert!(obs.watchdog_fired(), "no stall reported despite 120 ms dwell at 30 ms deadline");
    let diags = obs.diagnostics();
    let stall = diags.iter().find(|d| d.code == codes::WATCHDOG_STALL).unwrap();
    assert!(stall.message.contains("all_gather"), "{}", stall.message);
    assert!(stall.message.contains("embed"), "{}", stall.message);

    // the postmortem names the incident and round-trips as JSON
    let pm = obs.postmortem();
    assert_eq!(pm.get("schema").and_then(Json::as_str), Some("fsdp-postmortem-v1"));
    assert_eq!(pm.get("ranks").and_then(Json::as_f64), Some(4.0));
    let rings = pm.get("events").and_then(Json::as_arr).unwrap();
    assert_eq!(rings.len(), 4);
    let health = pm.get("health").unwrap().get("ranks").and_then(Json::as_arr).unwrap();
    assert_eq!(health.len(), 4);
    let dumped = pm.to_string();
    let parsed = Json::parse(&dumped).unwrap();
    let codes_in_pm: Vec<&str> = parsed
        .get("diagnostics")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .filter_map(|d| d.get("code").and_then(Json::as_str))
        .collect();
    assert!(codes_in_pm.contains(&codes::WATCHDOG_STALL), "{codes_in_pm:?}");

    // and writes to disk through the typed-error path
    let path = std::env::temp_dir().join(format!("fsdp_health_pm_{}.json", std::process::id()));
    let path = path.to_str().unwrap().to_string();
    obs.write_postmortem(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert!(Json::parse(&text).is_ok());
    let _ = std::fs::remove_file(&path);
    obs.shutdown();
}

#[test]
fn stagger_without_watchdog_stays_quiet() {
    // same injected straggler, but watchdog_ms = 0: the board records,
    // nothing fires
    let obs = Observer::new(ObsConfig::default(), 4);
    let comm = ThreadedComm::with_obs(Tracer::off(), Topology::flat(), obs.clone());
    let (m, s) = (4usize, 16 * 1024usize);
    let mut bufs: Vec<Vec<f32>> = (0..m).map(|_| vec![1.0f32; m * s]).collect();
    set_arrival_stagger(&[50_000]);
    let result = comm.all_gather(&mut bufs, s);
    set_arrival_stagger(&[]);
    result.unwrap();
    assert!(!obs.watchdog_fired());
    assert!(obs.diagnostics().is_empty());
    obs.shutdown();
}
