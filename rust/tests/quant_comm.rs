//! End-to-end tests for the block-wise quantized communication subsystem:
//! planner × quant-block alignment (property test over ragged sizes and
//! mesh widths), `F32` bit-identity with the pre-quantization engine
//! across {serial, threaded} × {sequential, pipelined}, `Q8`
//! determinism across backends and schedules, measured wire-byte
//! reduction, and convergence of the error-feedback quantized path.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::spec::{GroupFilter, ModelSpec, OptimBinding, ShardGroupSpec};
use vescale_fsdp::fsdp::{ExecMode, FsdpEngine, ShardingPolicy};
use vescale_fsdp::mesh::DeviceMesh;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::train::{TrainSession, Trainer};
use vescale_fsdp::util::Rng;

// ---- planner × quant alignment ------------------------------------------

#[test]
fn planner_keeps_quant_blocks_and_scales_on_one_device() {
    let mut rng = Rng::new(0x5170);
    for trial in 0..60u64 {
        let m = [1usize, 2, 4, 8][(trial % 4) as usize];
        let block = [8usize, 32][(trial as usize / 4) % 2];
        let n_tensors = 1 + (rng.below(4) as usize);
        let params: Vec<(String, Vec<usize>)> = (0..n_tensors)
            .map(|i| {
                let rows = 1 + rng.below(48) as usize;
                let cols = [1usize, 3, 8, 16][rng.below(4) as usize];
                (format!("t{i}.w"), vec![rows, cols])
            })
            .collect();
        let policy = if trial % 3 == 0 {
            ShardingPolicy::uniform_rows(2)
        } else {
            ShardingPolicy::element_wise()
        };
        let spec = ModelSpec::new().group(
            ShardGroupSpec::new("all", GroupFilter::Rest)
                .policy(policy)
                .comm_precision(CommPrecision::Q8 { block }),
        );
        let engine = FsdpEngine::from_spec(
            params.clone(),
            &spec,
            DeviceMesh::flat("fsdp", m),
            Fabric::h800(),
            std::sync::Arc::new(vescale_fsdp::cluster::SerialComm::new()),
        )
        .unwrap_or_else(|e| panic!("trial {trial} failed to plan: {e}"));
        let layout = &engine.buckets[0].dbuffer.layout;
        layout.verify().unwrap();
        // (1) the per-device shard is a whole number of quant blocks, so
        // shard-flat quantization never straddles a device and every
        // scale belongs to exactly one device
        assert_eq!(
            layout.shard_size % block as u64,
            0,
            "trial {trial}: shard {} not block-aligned",
            layout.shard_size
        );
        // (2) tensor granularities absorbed the block (tensors smaller
        // than one block shard whole on a single device)
        for (i, t) in layout.tensors.iter().enumerate() {
            assert!(
                t.granularity % block as u64 == 0 || t.granularity == t.numel,
                "trial {trial}: tensor {i} granularity {}",
                t.granularity
            );
            // (3) per-device slices of block-aligned tensors start on
            // block boundaries and only the final (tail) slice may end
            // off one
            if t.granularity % block as u64 == 0 {
                for rank in 0..m {
                    if let Some((lo, hi)) = layout.local_slice(i, rank) {
                        assert_eq!(lo % block as u64, 0, "trial {trial}: tensor {i} rank {rank}");
                        assert!(
                            hi % t.granularity == 0 || hi == t.numel,
                            "trial {trial}: tensor {i} rank {rank} hi {hi}"
                        );
                    }
                }
            }
        }
    }
}

// ---- F32 bit-identity with the PR-3 path --------------------------------

fn run_session(
    prec: Option<CommPrecision>,
    backend: CommBackend,
    exec: ExecMode,
    steps: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let mut b = TrainSession::builder("tiny")
        .devices(2)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .backend(backend)
        .exec(exec);
    if let Some(p) = prec {
        b = b.comm_precision(p);
    }
    let mut t = b.build().unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let params = (0..t.engine.params.len())
        .map(|i| t.engine.read_param(i))
        .collect();
    (losses, params)
}

fn assert_bit_identical(a: &(Vec<f32>, Vec<Vec<f32>>), b: &(Vec<f32>, Vec<Vec<f32>>), what: &str) {
    for (i, (x, y)) in a.0.iter().zip(&b.0).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss {i}: {x} vs {y}");
    }
    for (i, (pa, pb)) in a.1.iter().zip(&b.1).enumerate() {
        for (x, y) in pa.iter().zip(pb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {i}");
        }
    }
}

#[test]
fn f32_sessions_bit_identical_to_legacy_path() {
    // explicit CommPrecision::F32 must change nothing vs the legacy
    // constructor (the pre-quantization PR-3 trajectory), on every
    // backend × schedule combination
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Sequential),
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 1 }),
    ] {
        let mut legacy = Trainer::with_exec(
            "tiny",
            2,
            OptimKind::AdamW,
            &ShardingPolicy::element_wise(),
            AdamHyper { lr: 1e-3, ..AdamHyper::default() },
            42,
            backend,
            exec,
        )
        .unwrap();
        let mut legacy_losses = Vec::new();
        for _ in 0..2 {
            legacy_losses.push(legacy.train_step().unwrap());
        }
        let legacy_params: Vec<Vec<f32>> = (0..legacy.engine.params.len())
            .map(|i| legacy.engine.read_param(i))
            .collect();
        let explicit = run_session(Some(CommPrecision::F32), backend, exec, 2);
        assert_bit_identical(
            &(legacy_losses, legacy_params),
            &explicit,
            &format!("{} {}", backend.name(), exec.name()),
        );
    }
}

// ---- Q8 determinism across backends and schedules -----------------------

#[test]
fn q8_trajectory_bit_identical_across_backends_and_schedules() {
    let prec = CommPrecision::Q8 { block: 64 };
    let reference = run_session(Some(prec), CommBackend::Serial, ExecMode::Sequential, 3);
    for (backend, exec) in [
        (CommBackend::Serial, ExecMode::Pipelined { prefetch: 2 }),
        (CommBackend::Threaded, ExecMode::Sequential),
        (CommBackend::Threaded, ExecMode::Pipelined { prefetch: 2 }),
    ] {
        let r = run_session(Some(prec), backend, exec, 3);
        assert_bit_identical(
            &reference,
            &r,
            &format!("q8 {} {}", backend.name(), exec.name()),
        );
    }
}

// ---- wire volume + convergence ------------------------------------------

struct PrecRun {
    losses: Vec<f32>,
    wire_total: u64,
    wire_scale: u64,
    wire_pad: u64,
    ef_groups: usize,
}

fn run_prec(prec: CommPrecision, steps: usize) -> PrecRun {
    let mut t = TrainSession::builder("tiny")
        .devices(2)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(42)
        .comm_precision(prec)
        .build()
        .unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let (mut total, mut scale, mut pad) = (0u64, 0u64, 0u64);
    for l in &t.log {
        total += l.wire_payload + l.wire_scale + l.wire_pad;
        scale += l.wire_scale;
        pad += l.wire_pad;
    }
    let ef_groups = t.engine.buckets.iter().filter(|b| !b.ef.is_empty()).count();
    PrecRun { losses, wire_total: total, wire_scale: scale, wire_pad: pad, ef_groups }
}

fn tail_avg(losses: &[f32]) -> f32 {
    let n = losses.len().min(5);
    losses[losses.len() - n..].iter().sum::<f32>() / n as f32
}

#[test]
fn quantized_wire_bytes_reduced_3x_and_q8_converges() {
    let steps = 15;
    let full = run_prec(CommPrecision::F32, steps);
    let bf = run_prec(CommPrecision::Bf16, steps);
    let q8 = run_prec(CommPrecision::Q8 { block: 64 }, steps);

    // measured (not estimated) wire-byte reduction
    assert!(full.wire_total > 0);
    assert_eq!(full.wire_scale, 0);
    assert_eq!(full.wire_pad, 0);
    let bf_ratio = full.wire_total as f64 / bf.wire_total as f64;
    assert!(bf_ratio > 1.9 && bf_ratio < 2.1, "bf16 ratio {bf_ratio}");
    let q8_ratio = full.wire_total as f64 / q8.wire_total as f64;
    assert!(q8_ratio >= 3.0, "q8 wire reduction only {q8_ratio}x");
    assert!(q8.wire_scale > 0, "q8 must ship scale bytes");

    // every Q8 group holds shard-sized error-feedback residuals
    assert_eq!(q8.ef_groups, 4, "tiny = embed|layer0|layer1|head");
    assert_eq!(full.ef_groups, 0, "F32 must not materialize residuals");

    // training still works: losses finite and decreasing, and the
    // quantized trajectories land near the f32 one
    for r in [&full, &bf, &q8] {
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(
            tail_avg(&r.losses) < r.losses[0] - 0.2,
            "no learning: {} -> {}",
            r.losses[0],
            tail_avg(&r.losses)
        );
    }
    let f = tail_avg(&full.losses);
    let b = tail_avg(&bf.losses);
    let q = tail_avg(&q8.losses);
    assert!((b - f).abs() / f < 0.06, "bf16 {b} vs f32 {f}");
    assert!((q - f).abs() / f < 0.10, "q8 {q} vs f32 {f}");
}

#[test]
fn step_log_csv_has_wire_columns() {
    let mut t = TrainSession::builder("tiny")
        .devices(2)
        .optimizer(OptimBinding::AdamW)
        .seed(1)
        .comm_precision(CommPrecision::Q8 { block: 64 })
        .build()
        .unwrap();
    t.train_step().unwrap();
    let path = vescale_fsdp::train::save_log("test_quant_wire_cols", &t.log).unwrap();
    let csv = std::fs::read_to_string(&path).unwrap();
    let header = csv.lines().next().unwrap();
    assert!(header.contains("wire_payload,wire_scale,wire_pad"), "{header}");
    let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
    let payload: u64 = row[row.len() - 5].parse().unwrap();
    let scale: u64 = row[row.len() - 4].parse().unwrap();
    assert!(payload > 0 && scale > 0, "measured wire columns missing");
    let _ = std::fs::remove_file(path);
}
