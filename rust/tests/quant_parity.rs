//! Golden-vector parity for the block-wise int8 quantizer: shared JSON
//! fixtures checked against BOTH the 8-bit-Adam linear kernels
//! (`optim::adam8bit`) and the `quant/` communication kernels, pinning
//! the Pallas reference semantics — absmax scale with the 1.0 zero-block
//! fallback, round half to **even** (`jnp.round`), clip to ±127. The same
//! fixture file is consumed by
//! `python/tests/test_blockwise_quant_golden.py` against the Pallas
//! kernel itself, so all three implementations are tied to one source of
//! truth.

use vescale_fsdp::optim::adam8bit;
use vescale_fsdp::quant;
use vescale_fsdp::util::json::Json;

const GOLDEN: &str = include_str!("fixtures/blockwise_quant_golden.json");

struct Case {
    name: String,
    block: usize,
    x: Vec<f32>,
    scales: Vec<f32>,
    q: Vec<i8>,
}

fn cases() -> Vec<Case> {
    let root = Json::parse(GOLDEN).expect("golden fixture parses");
    root.get("cases")
        .and_then(|c| c.as_arr())
        .expect("cases array")
        .iter()
        .map(|c| {
            let floats = |key: &str| -> Vec<f32> {
                c.get(key)
                    .and_then(|v| v.as_arr())
                    .unwrap_or_else(|| panic!("missing {key}"))
                    .iter()
                    .map(|v| v.as_f64().unwrap() as f32)
                    .collect()
            };
            Case {
                name: c.get("name").and_then(|v| v.as_str()).unwrap().to_string(),
                block: c.get("block").and_then(|v| v.as_usize()).unwrap(),
                x: floats("x"),
                scales: floats("scales"),
                q: c
                    .get("q")
                    .and_then(|v| v.as_arr())
                    .unwrap()
                    .iter()
                    .map(|v| v.as_f64().unwrap() as i8)
                    .collect(),
            }
        })
        .collect()
}

#[test]
fn fixture_is_well_formed() {
    let cs = cases();
    assert!(cs.len() >= 5);
    for c in &cs {
        assert_eq!(c.x.len() % c.block, 0, "{}: python kernel needs whole blocks", c.name);
        assert_eq!(c.x.len(), c.q.len(), "{}", c.name);
        assert_eq!(c.scales.len(), c.x.len() / c.block, "{}", c.name);
    }
}

#[test]
fn quant_module_matches_golden() {
    for c in cases() {
        let qt = quant::QBlockTensor::quantize(&c.x, c.block);
        assert_eq!(qt.codes, c.q, "{}: codes", c.name);
        assert_eq!(qt.scales.len(), c.scales.len(), "{}", c.name);
        for (got, want) in qt.scales.iter().zip(&c.scales) {
            assert_eq!(got.to_bits(), want.to_bits(), "{}: scale {got} vs {want}", c.name);
        }
    }
}

#[test]
fn adam8bit_linear_kernels_match_golden() {
    for c in cases() {
        let nb = c.x.len() / c.block;
        for b in 0..nb {
            let lo = b * c.block;
            let hi = lo + c.block;
            let mut q = vec![0i8; c.block];
            let scale = adam8bit::quant_block(&c.x[lo..hi], &mut q);
            assert_eq!(scale.to_bits(), c.scales[b].to_bits(), "{}: block {b}", c.name);
            assert_eq!(&q[..], &c.q[lo..hi], "{}: block {b} codes", c.name);
        }
    }
}

#[test]
fn dequant_matches_reference_formula_in_both_impls() {
    // the Pallas dequant is q * scale / 127 — both Rust implementations
    // must produce exactly those bits
    for c in cases() {
        let qt = quant::QBlockTensor {
            codes: c.q.clone(),
            scales: c.scales.clone(),
            block: c.block,
            len: c.x.len(),
        };
        let via_quant = qt.dequantize();
        let mut via_adam8 = vec![0.0f32; c.x.len()];
        for (b, &s) in c.scales.iter().enumerate() {
            let lo = b * c.block;
            let hi = lo + c.block;
            adam8bit::dequant_block(&c.q[lo..hi], s, &mut via_adam8[lo..hi]);
        }
        for i in 0..c.x.len() {
            let expect = c.q[i] as f32 * c.scales[i / c.block] / 127.0;
            assert_eq!(via_quant[i].to_bits(), expect.to_bits(), "{}: [{i}]", c.name);
            assert_eq!(via_adam8[i].to_bits(), expect.to_bits(), "{}: [{i}]", c.name);
        }
    }
}

#[test]
fn roundtrip_error_within_half_step_on_golden_inputs() {
    for c in cases() {
        let qt = quant::QBlockTensor::quantize(&c.x, c.block);
        let back = qt.dequantize();
        for (i, (&orig, &got)) in c.x.iter().zip(&back).enumerate() {
            let step = qt.scales[i / c.block] / 127.0;
            assert!(
                (orig - got).abs() <= step * 0.5 + 1e-7,
                "{}: [{i}] {orig} vs {got}",
                c.name
            );
        }
    }
}
