//! Differential property test for the unified launch pipeline: the same
//! `CollectiveLaunch` descriptors must produce bit-identical training
//! trajectories and identical collective span identities across
//! {serial, threaded} backends × {f32, bf16, q8:32} wire precisions ×
//! {flat, 2x4:2} topologies × {sequential (sync launches), pipelined
//! (async issue/wait)} schedules. Losses are additionally pinned to one
//! per-precision reference, so no (backend, topology, schedule) cell can
//! drift on its own.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::comm::{Fabric, Topology};
use vescale_fsdp::fsdp::spec::OptimBinding;
use vescale_fsdp::fsdp::ExecMode;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::quant::CommPrecision;
use vescale_fsdp::trace::TraceLevel;
use vescale_fsdp::train::TrainSession;

/// Every (name, phase) lane a logical collective span can occupy.
const LANES: [(&str, &str); 6] = [
    ("ag", "sync"),
    ("rs", "sync"),
    ("ag", "issue"),
    ("ag", "wait"),
    ("rs", "issue"),
    ("rs", "wait"),
];

type Spans = Vec<(u64, String, String, String, u64)>;

fn run(
    backend: CommBackend,
    exec: ExecMode,
    prec: CommPrecision,
    topo: Option<Topology>,
) -> (Vec<f32>, Spans) {
    let mut b = TrainSession::builder("tiny")
        .devices(8)
        .optimizer(OptimBinding::AdamW)
        .hyper(AdamHyper { lr: 1e-3, ..AdamHyper::default() })
        .seed(11)
        .backend(backend)
        .exec(exec)
        .comm_precision(prec)
        .trace(TraceLevel::Comm);
    if let Some(t) = topo {
        b = b.fabric(Fabric::h800().with_topology(t));
    }
    let mut s = b.build().unwrap();
    let mut losses = Vec::new();
    for _ in 0..2 {
        losses.push(s.train_step().unwrap());
    }
    (losses, s.tracer.collective_sequence())
}

fn lane(spans: &Spans, step: u64, name: &str, phase: &str) -> Vec<(String, u64)> {
    spans
        .iter()
        .filter(|s| s.0 == step && s.1 == name && s.3 == phase)
        .map(|s| (s.2.clone(), s.4))
        .collect()
}

/// Span identity = the per-(name, phase) sequence of (bucket, bytes) of
/// each step — invariant across thread interleavings, unlike the merged
/// global order.
fn assert_span_identities_equal(a: &Spans, b: &Spans, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: collective span count");
    let mut steps: Vec<u64> = a.iter().map(|s| s.0).collect();
    steps.dedup();
    for &step in &steps {
        for (name, phase) in LANES {
            assert_eq!(
                lane(a, step, name, phase),
                lane(b, step, name, phase),
                "{what}: step {step} {name}/{phase} span identities diverge"
            );
        }
    }
}

fn assert_losses_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: loss count");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: loss {i}: {x} vs {y}");
    }
}

#[test]
fn unified_launch_bit_identical_across_backend_precision_topology_mode() {
    let hier = Topology { hosts: 2, gpus_per_host: 4, segments: 2 };
    for prec in [
        CommPrecision::F32,
        CommPrecision::Bf16,
        CommPrecision::Q8 { block: 32 },
    ] {
        // one reference trajectory per precision: serial, sync, flat
        let reference = run(CommBackend::Serial, ExecMode::Sequential, prec, None);
        for topo in [None, Some(hier)] {
            for exec in [ExecMode::Sequential, ExecMode::Pipelined { prefetch: 2 }] {
                let what = format!(
                    "{} topo={} exec={}",
                    prec.name(),
                    topo.map_or("flat".to_string(), |t| t.label()),
                    exec.name()
                );
                let serial = run(CommBackend::Serial, exec, prec, topo);
                let threaded = run(CommBackend::Threaded, exec, prec, topo);
                assert_losses_equal(&reference.0, &serial.0, &format!("{what} serial"));
                assert_losses_equal(&reference.0, &threaded.0, &format!("{what} threaded"));
                assert_span_identities_equal(&serial.1, &threaded.1, &what);
            }
        }
    }
}
