//! Failure injection and edge-case hardening: wrong-shaped inputs, corrupt
//! checkpoints, degenerate meshes, adversarial planner inputs. None of
//! these need the PJRT artifacts.

use vescale_fsdp::checkpoint;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::dtensor::DTensor;
use vescale_fsdp::fsdp::{FsdpEngine, ShardingPolicy};
use vescale_fsdp::mesh::DeviceMesh;
use vescale_fsdp::placement::{Placement, RaggedSpec};
use vescale_fsdp::planner::{plan, TensorDecl};

fn engine(m: usize) -> FsdpEngine {
    FsdpEngine::new(
        vec![("w".to_string(), vec![16, 16]), ("b".to_string(), vec![16])],
        &[0, 0],
        DeviceMesh::flat("fsdp", m),
        &ShardingPolicy::element_wise(),
        Fabric::h800(),
    )
    .unwrap()
}

#[test]
fn engine_rejects_wrong_param_arity() {
    let mut e = engine(2);
    assert!(e.init_params(&[vec![0.0; 256]]).is_err()); // one of two
}

#[test]
fn engine_rejects_wrong_grad_device_count() {
    let mut e = engine(2);
    e.init_params(&[vec![0.0; 256], vec![0.0; 16]]).unwrap();
    let one_dev = vec![vec![vec![0.0; 256], vec![0.0; 16]]];
    assert!(e.reduce_grads(&one_dev).is_err());
}

#[test]
fn engine_rejects_wrong_optimizer_arity() {
    let mut e = engine(2);
    e.init_params(&[vec![0.0; 256], vec![0.0; 16]]).unwrap();
    let mut none: Vec<Box<dyn vescale_fsdp::optim::ShardOptimizer>> = vec![];
    assert!(e.optimizer_step(&mut none, 1).is_err());
}

#[test]
fn single_device_mesh_degenerates_gracefully() {
    // m=1: no real sharding, everything still works end to end
    let mut e = engine(1);
    let p = vec![
        (0..256).map(|i| i as f32).collect::<Vec<f32>>(),
        (0..16).map(|i| i as f32).collect(),
    ];
    e.init_params(&p).unwrap();
    e.gather_params().unwrap();
    assert_eq!(e.device_params(0)[0], p[0]);
    let grads = vec![vec![vec![1.0f32; 256], vec![1.0f32; 16]]];
    e.reduce_grads(&grads).unwrap();
}

#[test]
fn checkpoint_missing_file_errors() {
    let dir = std::env::temp_dir().join("vescale_ckpt_missing");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("meta.json"), "{\"mesh\": 2, \"params\": []}").unwrap();
    let mut e = engine(2);
    assert!(checkpoint::load(&mut e, &dir).is_err());
}

#[test]
fn checkpoint_corrupt_meta_errors() {
    let dir = std::env::temp_dir().join("vescale_ckpt_corrupt");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("meta.json"), "not json at all").unwrap();
    let mut e = engine(2);
    assert!(checkpoint::load(&mut e, &dir).is_err());
}

#[test]
fn checkpoint_truncated_shard_errors() {
    let dir = std::env::temp_dir().join("vescale_ckpt_trunc");
    let _ = std::fs::remove_dir_all(&dir);
    let mut e = engine(2);
    e.init_params(&[vec![1.0; 256], vec![2.0; 16]]).unwrap();
    checkpoint::save(&e, &dir).unwrap();
    // truncate rank 1's shard
    let f = dir.join("rank_1.bin");
    let bytes = std::fs::read(&f).unwrap();
    std::fs::write(&f, &bytes[..bytes.len() / 2]).unwrap();
    let mut e2 = engine(2);
    assert!(checkpoint::load(&mut e2, &dir).is_err());
}

#[test]
fn redistribute_rejects_invalid_spec() {
    let data: Vec<f32> = (0..64).map(|i| i as f32).collect();
    let spec = RaggedSpec::balanced(64, 8, 4);
    let dt = DTensor::ragged_from_full(&[64], &data, spec).unwrap();
    // target spec covers the wrong number of blocks
    let bad = RaggedSpec { granularity: 8, blocks_per_device: vec![1, 1, 1, 1] };
    let fabric = Fabric::h800();
    let comm = vescale_fsdp::cluster::SerialComm::new();
    assert!(dt
        .redistribute(Placement::RaggedShard(bad), &comm, &fabric)
        .is_err());
}

#[test]
fn planner_handles_adversarial_inputs() {
    // single huge-granularity tensor (one indivisible block)
    let one = vec![TensorDecl::new("t", 1000, 1000)];
    let l = plan(&one, 4, 1).unwrap();
    l.verify().unwrap();
    assert!(l.shard_size >= 250);

    // coprime granularities
    let coprime = vec![
        TensorDecl::new("a", 7 * 11, 7),
        TensorDecl::new("b", 13 * 5, 13),
        TensorDecl::new("c", 17 * 3, 17),
    ];
    let l = plan(&coprime, 3, 1).unwrap();
    l.verify().unwrap();

    // many tiny tensors
    let tiny: Vec<TensorDecl> =
        (0..500).map(|i| TensorDecl::new(&format!("t{i}"), 3, 1)).collect();
    let l = plan(&tiny, 8, 16).unwrap();
    l.verify().unwrap();
    assert_eq!(l.shard_size % 16, 0);

    // granularity larger than the tensor is clamped by callers; planner
    // itself treats it as a single tail block
    let weird = vec![TensorDecl::new("w", 10, 64)];
    let l = plan(&weird, 2, 1).unwrap();
    l.verify().unwrap();
}

#[test]
fn zero_size_tensor_rejected_or_ignored() {
    // numel 0 is degenerate; planner must not panic
    let ts = vec![TensorDecl::new("z", 0, 1), TensorDecl::new("a", 8, 1)];
    if let Ok(l) = plan(&ts, 2, 1) {
        assert!(l.verify().is_ok());
    }
}

#[test]
fn policy_granularity_exceeding_tensor_is_clamped() {
    let params = vec![("small".to_string(), vec![4, 4])];
    let e = FsdpEngine::new(
        params,
        &[0],
        DeviceMesh::flat("fsdp", 8),
        &ShardingPolicy::uniform_rows(1024), // 1024 rows >> 4 rows
        Fabric::h800(),
    )
    .unwrap();
    // the whole tensor becomes one block on one device
    let spec = e.buckets[0].dbuffer.layout.ragged_spec(0);
    assert_eq!(spec.blocks_per_device.iter().sum::<u64>(), 1);
}

#[test]
fn hsdp_mesh_requires_fsdp_dim() {
    let bad = FsdpEngine::new(
        vec![("w".to_string(), vec![4, 4])],
        &[0],
        DeviceMesh::flat("replica", 2), // no "fsdp" dim
        &ShardingPolicy::element_wise(),
        Fabric::h800(),
    );
    assert!(bad.is_err());
}
