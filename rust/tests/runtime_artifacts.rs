//! PJRT-backed integration tests: load the AOT artifacts and verify the
//! L1/L2 numerics against the Rust host implementations.
//!
//! Requires a `--features pjrt` build *and* `make artifacts` (skipped
//! otherwise — the native runtime has its own coverage in
//! `src/runtime/native.rs` and `tests/backend_equivalence.rs`).

use vescale_fsdp::optim::{adam8bit, AdamHyper, AdamW};
use vescale_fsdp::optim::muon::{newton_schulz, NS_STEPS};
use vescale_fsdp::runtime::{Engine, In};
use vescale_fsdp::tensor::HostTensor;
use vescale_fsdp::util::Rng;

fn engine() -> Option<Engine> {
    if !Engine::pjrt_enabled() {
        eprintln!("skipping: build with --features pjrt");
        return None;
    }
    if !Engine::default_dir().join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Engine::load_default().expect("engine"))
}

fn randvec(n: usize, seed: u64, scale: f32) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32() * scale).collect()
}

#[test]
fn adamw_chunk_matches_host() {
    let Some(mut e) = engine() else { return };
    let n = e.manifest.chunk;
    let h = [3.0f32, 1e-3, 0.9, 0.999, 1e-8, 0.01];
    let hyper = AdamHyper { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01 };
    let mut p = randvec(n, 0, 1.0);
    let g = randvec(n, 1, 1.0);
    let mut m = randvec(n, 2, 0.1);
    let mut v: Vec<f32> = randvec(n, 3, 0.01).iter().map(|x| x.abs()).collect();
    let (mut ph, mut mh, mut vh) = (p.clone(), m.clone(), v.clone());
    e.adamw_chunk(&h, &mut p, &g, &mut m, &mut v).unwrap();
    AdamW::apply(&hyper, 3, &mut ph, &g, &mut mh, &mut vh);
    for i in 0..n {
        assert!((p[i] - ph[i]).abs() < 1e-5, "p[{i}]: {} vs {}", p[i], ph[i]);
        assert!((v[i] - vh[i]).abs() < 1e-5);
    }
}

#[test]
fn adamw_chunk_handles_tail_padding() {
    let Some(mut e) = engine() else { return };
    let n = e.manifest.chunk + 1000; // forces 2 chunks with padded tail
    let h = [1.0f32, 1e-3, 0.9, 0.999, 1e-8, 0.0];
    let hyper = AdamHyper { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.0 };
    let mut p = randvec(n, 4, 1.0);
    let g = randvec(n, 5, 1.0);
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let (mut ph, mut mh, mut vh) = (p.clone(), m.clone(), v.clone());
    e.adamw_chunk(&h, &mut p, &g, &mut m, &mut v).unwrap();
    AdamW::apply(&hyper, 1, &mut ph, &g, &mut mh, &mut vh);
    for i in 0..n {
        assert!((p[i] - ph[i]).abs() < 1e-5);
    }
}

#[test]
fn quant_chunk_matches_host_blocks() {
    let Some(mut e) = engine() else { return };
    let n = e.manifest.chunk;
    let block = e.manifest.qblock;
    let x = randvec(n, 6, 2.0);
    let (codes, scales) = e.quant_chunk(&x).unwrap();
    assert_eq!(scales.len(), n / block);
    for b in 0..n / block {
        let mut q = vec![0i8; block];
        let s = adam8bit::quant_block(&x[b * block..(b + 1) * block], &mut q);
        assert!((s - scales[b]).abs() < 1e-6 * s.max(1.0), "scale[{b}]");
        for i in 0..block {
            assert_eq!(q[i] as f32, codes[b * block + i], "code[{b},{i}]");
        }
    }
}

#[test]
fn newton_schulz_artifact_matches_host() {
    let Some(mut e) = engine() else { return };
    // tiny config hidden-matrix shape
    let (r, c) = (128, 512);
    let g = randvec(r * c, 7, 1.0);
    let got = e.newton_schulz(r, c, &g).unwrap();
    let host = newton_schulz(&HostTensor::from_f32(&[r, c], g), NS_STEPS).unwrap();
    let mut max_diff = 0.0f32;
    for (a, b) in got.iter().zip(host.as_f32()) {
        max_diff = max_diff.max((a - b).abs());
    }
    // matmul order differs (tiled vs naive) — allow accumulation noise
    assert!(max_diff < 5e-3, "NS diverged: {max_diff}");
}

#[test]
fn train_step_loss_sane_and_grads_complete() {
    let Some(mut e) = engine() else { return };
    let cfg = e.manifest.configs["tiny"].clone();
    let params = vescale_fsdp::train::init_full_params(&cfg.params, 0);
    let mut corpus = vescale_fsdp::train::Corpus::new(cfg.vocab, 1);
    let (tokens, targets) = corpus.batch(cfg.batch, cfg.seq);
    let (loss, grads) = e.train_step("tiny", &params, &tokens, &targets).unwrap();
    // fresh model: loss near ln(V)
    let lnv = (cfg.vocab as f32).ln();
    assert!((loss - lnv).abs() < 1.0, "loss {loss} vs ln(V) {lnv}");
    assert_eq!(grads.len(), params.len());
    for (g, p) in grads.iter().zip(&params) {
        assert_eq!(g.len(), p.len());
        assert!(g.iter().all(|x| x.is_finite()));
    }
    // grads not all zero
    let norm: f32 = grads.iter().flat_map(|g| g.iter()).map(|x| x * x).sum();
    assert!(norm > 0.0);
}

#[test]
fn eval_loss_matches_train_step_loss() {
    let Some(mut e) = engine() else { return };
    let cfg = e.manifest.configs["tiny"].clone();
    let params = vescale_fsdp::train::init_full_params(&cfg.params, 2);
    let mut corpus = vescale_fsdp::train::Corpus::new(cfg.vocab, 3);
    let (tokens, targets) = corpus.batch(cfg.batch, cfg.seq);
    let (loss_t, _) = e.train_step("tiny", &params, &tokens, &targets).unwrap();
    let loss_e = e.eval_loss("tiny", &params, &tokens, &targets).unwrap();
    assert!((loss_t - loss_e).abs() < 1e-5, "{loss_t} vs {loss_e}");
}

#[test]
fn exec_validates_arity() {
    let Some(mut e) = engine() else { return };
    let x = vec![0.0f32; 8];
    assert!(e.exec("adamw_chunk", &[In::F32(&x, vec![8])]).is_err());
    assert!(e.exec("no_such_artifact", &[]).is_err());
}

#[test]
fn executable_cache_compiles_once() {
    let Some(mut e) = engine() else { return };
    let n = e.manifest.chunk;
    let h = [1.0f32, 1e-3, 0.9, 0.999, 1e-8, 0.0];
    let mut p = vec![0.1f32; n];
    let g = vec![0.01f32; n];
    let (mut m, mut v) = (vec![0.0f32; n], vec![0.0f32; n]);
    let t0 = std::time::Instant::now();
    e.adamw_chunk(&h, &mut p, &g, &mut m, &mut v).unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    for _ in 0..3 {
        e.adamw_chunk(&h, &mut p, &g, &mut m, &mut v).unwrap();
    }
    let warm = t1.elapsed() / 3;
    assert!(warm < first, "cache ineffective: {warm:?} vs {first:?}");
    assert_eq!(e.exec_counts["adamw_chunk"], 4);
}
