//! Backend equivalence: the threaded SPMD backend must be **bit-identical**
//! to the serial reference — property tests over every collective at mesh
//! sizes 1/2/4/8 with ragged (non-divisible) shard sizes, plus end-to-end
//! training runs whose loss trajectories and final parameters must match
//! to the bit.

use vescale_fsdp::cluster::{CommBackend, Communicator, SerialComm, ThreadedComm};
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{DdpTrainer, Trainer};
use vescale_fsdp::util::prop::{check, Case};
use vescale_fsdp::util::Rng;

const MESHES: [usize; 4] = [1, 2, 4, 8];

/// Values spread over many exponents: any change in summation order
/// would actually flip result bits.
fn wild_bufs(rng: &mut Rng, m: usize, len: usize) -> Vec<Vec<f32>> {
    (0..m)
        .map(|_| {
            (0..len)
                .map(|_| rng.normal_f32() * 10f32.powi(rng.below(9) as i32 - 4))
                .collect()
        })
        .collect()
}

fn assert_bits_equal(a: &[Vec<f32>], b: &[Vec<f32>], what: &str) -> Result<(), String> {
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        for (i, (u, v)) in x.iter().zip(y).enumerate() {
            if u.to_bits() != v.to_bits() {
                return Err(format!("{what}: rank {k} elem {i}: {u} vs {v}"));
            }
        }
    }
    Ok(())
}

fn pick_mesh(case: &mut Case) -> usize {
    MESHES[case.rng.below(MESHES.len() as u64) as usize]
}

#[test]
fn all_gather_bit_identical_across_backends() {
    check("ag-backend-equiv", 40, |case| {
        let m = pick_mesh(case);
        let s = case.rng.range(1, case.scaled(33)); // incl. odd/ragged sizes
        let mut serial = wild_bufs(&mut case.rng, m, m * s);
        let mut threaded = serial.clone();
        SerialComm::new().all_gather(&mut serial, s).map_err(|e| e.to_string())?;
        ThreadedComm::with_min_parallel_elems(0).all_gather(&mut threaded, s).map_err(|e| e.to_string())?;
        assert_bits_equal(&serial, &threaded, &format!("all_gather m={m} s={s}"))
    });
}

#[test]
fn reduce_scatter_bit_identical_across_backends() {
    check("rs-backend-equiv", 40, |case| {
        let m = pick_mesh(case);
        let s = case.rng.range(1, case.scaled(33));
        let mut serial = wild_bufs(&mut case.rng, m, m * s);
        let mut threaded = serial.clone();
        let scale = 1.0 / m as f32;
        SerialComm::new()
            .reduce_scatter(&mut serial, s, scale)
            .map_err(|e| e.to_string())?;
        ThreadedComm::with_min_parallel_elems(0)
            .reduce_scatter(&mut threaded, s, scale)
            .map_err(|e| e.to_string())?;
        assert_bits_equal(&serial, &threaded, &format!("reduce_scatter m={m} s={s}"))
    });
}

#[test]
fn all_reduce_bit_identical_across_backends() {
    check("ar-backend-equiv", 40, |case| {
        let m = pick_mesh(case);
        // deliberately not a multiple of m (ragged range partition)
        let n = case.rng.range(1, case.scaled(77));
        let mut serial = wild_bufs(&mut case.rng, m, n);
        let mut threaded = serial.clone();
        SerialComm::new().all_reduce(&mut serial, 0.125).map_err(|e| e.to_string())?;
        ThreadedComm::with_min_parallel_elems(0)
            .all_reduce(&mut threaded, 0.125)
            .map_err(|e| e.to_string())?;
        assert_bits_equal(&serial, &threaded, &format!("all_reduce m={m} n={n}"))
    });
}

#[test]
fn broadcast_and_all_to_all_bit_identical_across_backends() {
    check("bc-a2a-backend-equiv", 40, |case| {
        let m = pick_mesh(case);
        let s = case.rng.range(1, case.scaled(17));
        let root = case.rng.below(m as u64) as usize;
        let mut serial = wild_bufs(&mut case.rng, m, m * s);
        let mut threaded = serial.clone();
        SerialComm::new().broadcast(&mut serial, root).map_err(|e| e.to_string())?;
        ThreadedComm::with_min_parallel_elems(0)
            .broadcast(&mut threaded, root)
            .map_err(|e| e.to_string())?;
        assert_bits_equal(&serial, &threaded, &format!("broadcast m={m} root={root}"))?;
        SerialComm::new().all_to_all(&mut serial, s).map_err(|e| e.to_string())?;
        ThreadedComm::with_min_parallel_elems(0).all_to_all(&mut threaded, s).map_err(|e| e.to_string())?;
        assert_bits_equal(&serial, &threaded, &format!("all_to_all m={m} s={s}"))
    });
}

// ---- end-to-end trajectories -------------------------------------------

fn run_fsdp(backend: CommBackend, m: usize, opt: OptimKind, steps: usize) -> (Vec<f32>, Vec<Vec<f32>>) {
    let hyper = match opt {
        OptimKind::Muon => AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
        _ => AdamHyper { lr: 1e-3, ..AdamHyper::default() },
    };
    let policy = if opt == OptimKind::Adam8bit {
        ShardingPolicy::uniform_rows(32)
    } else {
        ShardingPolicy::element_wise()
    };
    let mut t = Trainer::with_backend("tiny", m, opt, &policy, hyper, 42, backend).unwrap();
    let mut losses = Vec::new();
    for _ in 0..steps {
        losses.push(t.train_step().unwrap());
    }
    let params = (0..t.engine.params.len()).map(|i| t.engine.read_param(i)).collect();
    (losses, params)
}

#[test]
fn fsdp_threaded_trajectory_bit_identical_to_serial() {
    let (ls, ps) = run_fsdp(CommBackend::Serial, 4, OptimKind::AdamW, 3);
    let (lt, pt) = run_fsdp(CommBackend::Threaded, 4, OptimKind::AdamW, 3);
    for (step, (a, b)) in ls.iter().zip(&lt).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "step {step}: {a} vs {b}");
    }
    assert_bits_equal(&ps, &pt, "final params").unwrap();
}

#[test]
fn muon_threaded_trajectory_bit_identical_to_serial() {
    // Muon goes through DTensor::redistribute -> threaded collectives
    let (ls, ps) = run_fsdp(CommBackend::Serial, 2, OptimKind::Muon, 2);
    let (lt, pt) = run_fsdp(CommBackend::Threaded, 2, OptimKind::Muon, 2);
    for (a, b) in ls.iter().zip(&lt) {
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
    }
    assert_bits_equal(&ps, &pt, "final params").unwrap();
}

#[test]
fn ddp_threaded_trajectory_bit_identical_to_serial() {
    let run = |backend| {
        let mut t = DdpTrainer::with_backend(
            "tiny",
            2,
            OptimKind::AdamW,
            AdamHyper { lr: 1e-3, ..AdamHyper::default() },
            42,
            backend,
        )
        .unwrap();
        let mut losses = Vec::new();
        for _ in 0..2 {
            losses.push(t.train_step().unwrap());
        }
        (losses, t.params)
    };
    let (ls, ps) = run(CommBackend::Serial);
    let (lt, pt) = run(CommBackend::Threaded);
    for (a, b) in ls.iter().zip(&lt) {
        assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
    }
    assert_bits_equal(&ps, &pt, "ddp params").unwrap();
}

#[test]
fn threaded_stats_match_serial_stats() {
    // same collectives recorded, same simulated bytes/time, either backend
    let run = |backend| {
        let mut t = Trainer::with_backend(
            "tiny",
            2,
            OptimKind::AdamW,
            &ShardingPolicy::element_wise(),
            AdamHyper::default(),
            7,
            backend,
        )
        .unwrap();
        t.train_step().unwrap();
        t.engine.stats()
    };
    let s = run(CommBackend::Serial);
    let t = run(CommBackend::Threaded);
    assert_eq!(s.count("all_gather"), t.count("all_gather"));
    assert_eq!(s.count("reduce_scatter"), t.count("reduce_scatter"));
    assert_eq!(s.total_bytes(), t.total_bytes());
    assert!((s.total_time() - t.total_time()).abs() < 1e-12);
}
