//! End-to-end integration: the numeric FSDP engine + compute runtime
//! train a real (tiny) transformer and match the DDP reference
//! trajectory. Runs on the native compute path out of the box; with
//! `--features pjrt` + `make artifacts` the same tests exercise the AOT
//! executables instead.

use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{DdpTrainer, Trainer};

fn hyper() -> AdamHyper {
    AdamHyper { lr: 1e-3, beta1: 0.9, beta2: 0.999, eps: 1e-8, wd: 0.01 }
}

#[test]
fn fsdp_training_reduces_loss() {
    let mut t = Trainer::new(
        "tiny",
        2,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        hyper(),
        42,
    )
    .unwrap();
    let log = t.run(12).unwrap();
    let first = log[0].loss;
    let last = log.last().unwrap().loss;
    assert!(
        last < first - 0.3,
        "loss did not decrease: {first} -> {last}"
    );
}

#[test]
fn fsdp_matches_ddp_trajectory_adamw() {
    // same seeds, same data, same optimizer: FSDP (layer-wise RS) and DDP
    // (bucketed AR) must track each other closely for fp32 AdamW
    let m = 2;
    let mut fsdp = Trainer::new(
        "tiny",
        m,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        hyper(),
        7,
    )
    .unwrap();
    let mut ddp = DdpTrainer::new("tiny", m, OptimKind::AdamW, hyper(), 7).unwrap();
    let fl = fsdp.run(6).unwrap();
    let dl = ddp.run(6).unwrap();
    for (a, b) in fl.iter().zip(&dl) {
        assert!(
            (a.loss - b.loss).abs() < 5e-3,
            "step {}: fsdp {} vs ddp {}",
            a.step,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn adam8bit_with_ragged_blocks_trains() {
    // 32-row granularity so every quant block stays on one device
    let mut t = Trainer::new(
        "tiny",
        2,
        OptimKind::Adam8bit,
        &ShardingPolicy::uniform_rows(32),
        hyper(),
        11,
    )
    .unwrap();
    let log = t.run(10).unwrap();
    assert!(log.last().unwrap().loss < log[0].loss - 0.2);
}

#[test]
fn muon_trains_and_beats_nothing_blows_up() {
    let mut t = Trainer::new(
        "tiny",
        2,
        OptimKind::Muon,
        &ShardingPolicy::element_wise(),
        AdamHyper { lr: 0.02, wd: 0.0, ..hyper() },
        13,
    )
    .unwrap();
    let log = t.run(10).unwrap();
    assert!(log.iter().all(|l| l.loss.is_finite()));
    assert!(log.last().unwrap().loss < log[0].loss - 0.2);
}

#[test]
fn mesh_size_does_not_change_numerics() {
    let run_with = |m: usize| {
        let mut t = Trainer::new(
            "tiny",
            m,
            OptimKind::AdamW,
            &ShardingPolicy::element_wise(),
            hyper(),
            21,
        )
        .unwrap();
        // identical data across runs: corpus streams per device; use 1
        // device worth by comparing only the sharding math — instead we
        // check params after init + one gather round-trip
        t.engine.gather_params().unwrap();
        let p0 = t.engine.device_params(0);
        t.engine.release_params();
        (t, p0)
    };
    let (t2, p2) = run_with(2);
    let (t4, p4) = run_with(4);
    assert_eq!(p2.len(), p4.len());
    for (a, b) in p2.iter().zip(&p4) {
        assert_eq!(a, b, "init params differ across mesh sizes");
    }
    drop((t2, t4));
}

#[test]
fn comm_stats_recorded_per_step() {
    let mut t = Trainer::new(
        "tiny",
        2,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        hyper(),
        31,
    )
    .unwrap();
    t.train_step().unwrap();
    let buckets = t.engine.buckets.len();
    let stats = t.engine.stats();
    assert_eq!(stats.count("all_gather"), buckets);
    assert_eq!(stats.count("reduce_scatter"), buckets);
    assert!(stats.total_time() > 0.0);
}
