//! Composability demo (paper §4, Fig 5): RaggedShard composed with an
//! inner Shard(0)/Shard(1) (Expert/Tensor Parallelism), plus the 2-D HSDP
//! mesh, exercised through the symbolic engine at production scales.
//!
//!     cargo run --release --example moe_ep_compose

use vescale_fsdp::baselines;
use vescale_fsdp::comm::Fabric;
use vescale_fsdp::config::{presets, OptimKind, ParallelConfig};
use vescale_fsdp::fsdp::sim::{simulate_step, GpuSpec};
use vescale_fsdp::placement::compose_with_shard;
use vescale_fsdp::util::table::Table;

fn main() -> anyhow::Result<()> {
    // ---- placement-level composition rules ----
    println!("RaggedShard x Shard composition (paper §4):");
    // Shard(0) under RaggedShard -> StridedRaggedShard with reshuffle
    let (g, strided) = compose_with_shard(32, &[128, 5760, 2880], 0)?;
    println!("  Shard(0):  granularity {g} -> StridedRaggedShard (reshuffle: {strided})");
    // Shard(1): granularity snaps to LCM so blocks never cut the dim
    let (g, _) = compose_with_shard(1000, &[1024, 512], 1)?;
    println!("  Shard(1):  user 1000 -> LCM granularity {g}");

    // ---- FSDP x EP at scale on the 800B MoE ----
    let preset = presets::moe_internal(800.0);
    let fabric = Fabric::h800();
    let gpu = GpuSpec::h800();
    let mut table = Table::new(
        "FSDP x EP on the 800B MoE, 1024 GPUs (per-device 8K tokens)",
        &["layout", "step (s)", "exposed comm (s)", "tokens/s (global)"],
    );
    for ep in [1usize, 4, 8, 16] {
        let r = simulate_step(
            &preset,
            &ParallelConfig { fsdp: 1024, replicas: 1, ep },
            OptimKind::AdamW,
            8192,
            &fabric,
            &gpu,
            &baselines::vescale(1),
        )?;
        table.rowv(vec![
            if ep == 1 { "FSDP 1024".into() } else { format!("FSDP 1024 x EP {ep}") },
            format!("{:.2}", r.step_time),
            format!("{:.2}", r.exposed_comm),
            format!("{:.2e}", r.tokens_per_sec),
        ]);
    }
    table.print();

    // ---- HSDP: replication keeps memory nearly flat ----
    let llama = presets::llama70b();
    let mut t2 = Table::new(
        "HSDP on LLaMA-3-70B (paper Fig 8 sweep)",
        &["layout", "devices", "peak reserved (GB)", "tokens/s (global)"],
    );
    for (fsdp, reps) in [(128, 1), (256, 1), (256, 2), (256, 4)] {
        let r = simulate_step(
            &llama,
            &ParallelConfig { fsdp, replicas: reps, ep: 1 },
            OptimKind::AdamW,
            4096,
            &fabric,
            &gpu,
            &baselines::vescale(1),
        )?;
        t2.rowv(vec![
            if reps > 1 { format!("HSDP {reps}x{fsdp}") } else { format!("FSDP {fsdp}") },
            format!("{}", fsdp * reps),
            format!("{:.1}", r.peak_reserved as f64 / 1e9),
            format!("{:.2e}", r.tokens_per_sec),
        ]);
    }
    t2.print();
    Ok(())
}
