//! Planner CLI: run Algorithm 1 on a model preset and inspect the layout
//! — shard size, padding, block integrity, per-ordering comparison.
//!
//!     cargo run --release --example planner_cli -- \
//!         [--preset gptoss120b] [--devices 64] [--rows 128]

use vescale_fsdp::config::presets;
use vescale_fsdp::planner::{plan_with_ordering, split_blocks, Ordering, TensorDecl};
use vescale_fsdp::util::args::Args;
use vescale_fsdp::util::table::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let name = args.str_or("preset", "gptoss120b");
    let m = args.usize_or("devices", 64);
    let rows = args.u64_or("rows", 128);
    let preset = presets::by_name(&name)
        .ok_or_else(|| anyhow::anyhow!("unknown preset '{name}'"))?;

    // DeepSeek-style scheme: quantize FFN/expert weights at `rows`-row
    // granularity; everything else element-wise
    let decls: Vec<TensorDecl> = preset
        .all_params()
        .iter()
        .map(|p| {
            let row = *p.shape.last().unwrap() as u64;
            let g = if p.name.contains("expert") || p.name.contains("mlp") {
                (rows * row).min(p.numel()).max(1)
            } else {
                1
            };
            TensorDecl::new(&p.name, p.numel(), g)
        })
        .collect();
    println!(
        "preset {name}: {} tensors, {:.2}B params, {m} devices, {rows}-row granularity",
        decls.len(),
        preset.total_params() as f64 / 1e9
    );

    let mut table = Table::new(
        "Algorithm 1 orderings",
        &["ordering", "shard S (elems)", "padding", "split blocks", "plan time"],
    );
    for ord in [Ordering::Default, Ordering::ByGranularity, Ordering::BySize] {
        let t0 = std::time::Instant::now();
        let layout = plan_with_ordering(&decls, m, 4, ord)?;
        layout.verify()?;
        table.rowv(vec![
            format!("{ord:?}"),
            format!("{}", layout.shard_size),
            format!("{:.4}%", layout.padding_ratio() * 100.0),
            format!("{}", split_blocks(&layout)),
            format!("{:.3}s", t0.elapsed().as_secs_f64()),
        ]);
    }
    table.print();
    Ok(())
}
