//! Quickstart: shard a tiny transformer with the fully_shard-style API,
//! run a few training steps on a simulated 4-device mesh, print the loss.
//!
//!     cargo run --release --example quickstart

use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::Trainer;

fn main() -> anyhow::Result<()> {
    // fully_shard the `tiny` model over 4 simulated devices, element-wise
    // RaggedShard granularity (the production default)
    let mut trainer = Trainer::new(
        "tiny",
        4,
        OptimKind::AdamW,
        &ShardingPolicy::element_wise(),
        AdamHyper::default(),
        42,
    )?;

    println!("model: tiny | devices: 4 | optimizer: adamw");
    println!(
        "sharded elements/device: {} (padding {:.3}%)",
        trainer.engine.shard_elems(),
        trainer.engine.padding_ratio() * 100.0
    );

    for step in 1..=20 {
        let loss = trainer.train_step()?;
        if step % 5 == 0 || step == 1 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }
    let s = trainer.engine.stats();
    println!(
        "collectives: {} AllGather + {} ReduceScatter, {:.1} MB moved, {:.1} ms simulated",
        s.count("all_gather"),
        s.count("reduce_scatter"),
        s.total_bytes() as f64 / 1e6,
        s.total_time() * 1e3,
    );
    Ok(())
}
