//! Quickstart: shard a tiny transformer with the declarative
//! `fully_shard`-style spec API, bind a *different optimizer per wrap
//! unit* (Muon on layer matrices, AdamW on embed/head — the paper's §6.3
//! mixed setup), run a few training steps on a simulated 4-device mesh,
//! print the loss.
//!
//!     cargo run --release --example quickstart

use vescale_fsdp::fsdp::spec::ModelSpec;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::TrainSession;

fn main() -> anyhow::Result<()> {
    // fully_shard the `tiny` model over 4 simulated devices: the
    // layerwise wrap graph (embed | layer i | head) with Muon bound to
    // the layer groups and AdamW everywhere else
    let spec = ModelSpec::layerwise_mixed_muon(
        2, // tiny has 2 layers
        AdamHyper { lr: 0.02, wd: 0.0, ..AdamHyper::default() },
    );
    let mut session = TrainSession::builder("tiny")
        .devices(4)
        .spec(spec)
        .hyper(AdamHyper::default()) // embed/head AdamW hyper
        .seed(42)
        .build()?;

    println!("model: tiny | devices: 4 | per-group optimizers:");
    for (bucket, opt) in session.engine.buckets.iter().zip(&session.optimizers) {
        println!("  {:>8} -> {}", bucket.name, opt.name());
    }
    println!(
        "sharded elements/device: {} (padding {:.3}%)",
        session.engine.shard_elems(),
        session.engine.padding_ratio() * 100.0
    );

    for step in 1..=20 {
        let loss = session.train_step()?;
        if step % 5 == 0 || step == 1 {
            println!("step {step:>3}  loss {loss:.4}");
        }
    }
    let s = session.engine.stats();
    println!(
        "collectives: {} AllGather + {} ReduceScatter, {:.1} MB moved, {:.1} ms simulated on {}",
        s.count("all_gather"),
        s.count("reduce_scatter"),
        s.total_bytes() as f64 / 1e6,
        s.total_time() * 1e3,
        session.engine.fabric.name,
    );
    Ok(())
}
