//! Paper §6.3 case study: distributed Muon (Algorithm 2) vs AdamW on the
//! same model/data — Muon should converge faster (lower loss at equal
//! steps). Muon's parameter gather is a plain RaggedShard redistribute.
//!
//!     cargo run --release --example muon_vs_adamw -- [--steps 120]

use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{save_log, Trainer};
use vescale_fsdp::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 120);
    let mesh = args.usize_or("mesh", 4);
    let config = args.str_or("config", "tiny");

    let mut results = Vec::new();
    for (opt, lr) in [(OptimKind::AdamW, 1e-3f32), (OptimKind::Muon, 0.02)] {
        let hyper = AdamHyper { lr, wd: 0.0, ..AdamHyper::default() };
        let mut t = Trainer::new(&config, mesh, opt, &ShardingPolicy::element_wise(), hyper, 42)?;
        println!("-- {} (lr={lr}) --", opt.name());
        for step in 1..=steps {
            let loss = t.train_step()?;
            if step % 20 == 0 {
                println!("step {step:>4}  loss {loss:.4}");
            }
        }
        let tail: Vec<f32> = t.log.iter().rev().take(10).map(|l| l.loss).collect();
        let final_loss = tail.iter().sum::<f32>() / tail.len() as f32;
        save_log(&format!("muon_cmp_{}", opt.name()), &t.log)?;
        results.push((opt.name(), final_loss));
    }
    println!("\nfinal loss (avg last 10): {} {:.4} vs {} {:.4}",
             results[0].0, results[0].1, results[1].0, results[1].1);
    if results[1].1 < results[0].1 {
        println!("Muon converges faster, as in Fig 10b.");
    }
    Ok(())
}
