//! Paper §6.3 case study: block-wise 8-bit Adam under FSDP vs DDP.
//!
//! The `orig_param_policy` (here `ShardingPolicy::uniform_rows(32)`)
//! assigns matrix parameters 32-row RaggedShard granularity, so every
//! 32x32 quantization block lives entirely on one device — no metadata
//! exchange, no intrusive model changes. The FSDP and DDP loss curves
//! should track closely (Fig 10a).
//!
//!     cargo run --release --example adam8bit -- [--steps 100]

use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{save_log, DdpTrainer, Trainer};
use vescale_fsdp::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let steps = args.usize_or("steps", 100);
    let mesh = args.usize_or("mesh", 4);
    let hyper = AdamHyper { lr: 5e-4, ..AdamHyper::default() }; // smaller lr, as the paper notes
    let config = args.str_or("config", "tiny");

    println!("-- 8-bit Adam under veScale-FSDP (32-row RaggedShard blocks) --");
    let mut fsdp = Trainer::new(
        &config,
        mesh,
        OptimKind::Adam8bit,
        &ShardingPolicy::uniform_rows(32),
        hyper,
        42,
    )?;
    for step in 1..=steps {
        let loss = fsdp.train_step()?;
        if step % 20 == 0 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    save_log("adam8bit_fsdp", &fsdp.log)?;

    println!("-- 8-bit Adam under DDP (reference) --");
    let mut ddp = DdpTrainer::new(&config, mesh, OptimKind::Adam8bit, hyper, 42)?;
    for step in 1..=steps {
        let loss = ddp.train_step()?;
        if step % 20 == 0 {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    save_log("adam8bit_ddp", &ddp.log)?;

    let f = fsdp.log.last().unwrap().loss;
    let d = ddp.log.last().unwrap().loss;
    println!("\nfinal: FSDP {f:.4} vs DDP {d:.4} (gap {:.4})", (f - d).abs());
    println!("loss curves track closely; the residual gap is the gradient-");
    println!("reduction schedule (layer-wise RS vs bucketed AR), Fig 10a.");
    Ok(())
}
