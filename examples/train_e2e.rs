//! End-to-end validation driver: train a real transformer with the full
//! three-layer stack — Rust coordinator (RaggedShard + planner + DBuffer
//! collectives + sharded optimizer) executing the L2 fwd/bwd on every
//! simulated device (PJRT artifacts when built with `--features pjrt`,
//! the native Rust compute path otherwise) — and log the loss curve.
//!
//!     cargo run --release --example train_e2e -- \
//!         [--config tiny|small] [--mesh 4] [--steps 300] [--opt adamw] \
//!         [--backend serial|threaded]
//!
//! The loss log lands in runs/<name>.csv and is summarized on stdout.

use vescale_fsdp::cluster::CommBackend;
use vescale_fsdp::config::OptimKind;
use vescale_fsdp::fsdp::ShardingPolicy;
use vescale_fsdp::optim::AdamHyper;
use vescale_fsdp::train::{save_log, Trainer};
use vescale_fsdp::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let config = args.str_or("config", "tiny");
    let mesh = args.usize_or("mesh", 4);
    let steps = args.usize_or("steps", 300);
    let opt = OptimKind::parse(&args.str_or("opt", "adamw"))
        .ok_or_else(|| anyhow::anyhow!("unknown --opt"))?;
    let backend = CommBackend::parse(&args.str_or("backend", "threaded"))
        .ok_or_else(|| anyhow::anyhow!("unknown --backend"))?;
    let lr = args.f64_or("lr", 1e-3) as f32;
    let granularity_rows = args.usize_or("rows", 0) as u64;

    let policy = if granularity_rows > 0 || opt == OptimKind::Adam8bit {
        // 8-bit Adam needs quant blocks intact on one device: 32-row blocks
        ShardingPolicy::uniform_rows(if granularity_rows > 0 { granularity_rows } else { 32 })
    } else {
        ShardingPolicy::element_wise()
    };
    let hyper = AdamHyper { lr, ..AdamHyper::default() };

    println!("== veScale-FSDP end-to-end training ==");
    println!(
        "config={config} mesh={mesh} steps={steps} opt={} backend={}",
        opt.name(),
        backend.name()
    );
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::with_backend(&config, mesh, opt, &policy, hyper, 42, backend)?;
    println!("compute runtime: {}", trainer.runtime.backend_name());
    println!(
        "params: {} | shard/device: {} elems | padding {:.4}% | buckets {}",
        trainer.runtime.manifest.configs[&config].total_params(),
        trainer.engine.shard_elems(),
        trainer.engine.padding_ratio() * 100.0,
        trainer.engine.buckets.len(),
    );

    let mut window: Vec<f32> = Vec::new();
    for step in 1..=steps {
        let loss = trainer.train_step()?;
        window.push(loss);
        if window.len() > 20 {
            window.remove(0);
        }
        if step % 20 == 0 || step == 1 {
            let avg: f32 = window.iter().sum::<f32>() / window.len() as f32;
            println!(
                "step {step:>4}  loss {loss:.4}  (avg20 {avg:.4})  wall {:.1}s",
                t0.elapsed().as_secs_f64()
            );
        }
    }
    let name = format!("e2e_{config}_{}_{}dev_{}", opt.name(), mesh, backend.name());
    let path = save_log(&name, &trainer.log)?;
    let first = trainer.log[0].loss;
    let tail = trainer.log.iter().rev().take(20).map(|l| l.loss).collect::<Vec<_>>();
    let last20: f32 = tail.iter().sum::<f32>() / tail.len() as f32;
    println!("\nloss: {first:.4} -> {last20:.4} (avg of last 20)");
    println!(
        "simulated comm: {:.1} ms/step | tokens/step: {} | wall: {:.1}s total",
        trainer.engine.stats().total_time() * 1e3 / steps as f64,
        trainer.runtime.manifest.configs[&config].batch
            * trainer.runtime.manifest.configs[&config].seq
            * mesh,
        t0.elapsed().as_secs_f64(),
    );
    println!("loss log: {}", path.display());
    Ok(())
}
