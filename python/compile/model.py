"""L2: decoder-only transformer (fwd/bwd) in JAX, calling the L1 kernels.

This is the compute graph that the Rust coordinator executes per simulated
device through PJRT. The FFN matmuls go through the Pallas
``matmul_tiled`` kernel (custom-VJP), so the L1 kernel sits on the training
hot path and lowers into the same HLO module.

Parameters travel between Rust and HLO as a *flat ordered list* of f32
tensors; ``param_specs`` defines the canonical order, which aot.py writes
into the artifact manifest so both sides agree.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import matmul_tiled


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyper-parameters."""
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq: int
    batch: int  # per-device micro-batch

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


# Presets. Sizes are scaled for the single-core CPU substrate (DESIGN.md §1);
# `tiny` is the test config, `small` the e2e training config, `mid100m` the
# ~100M-parameter config the e2e driver can optionally run.
CONFIGS: Dict[str, ModelConfig] = {
    "tiny": ModelConfig("tiny", vocab=512, d_model=128, n_layers=2,
                        n_heads=4, d_ff=512, seq=64, batch=4),
    "small": ModelConfig("small", vocab=2048, d_model=256, n_layers=4,
                         n_heads=4, d_ff=1024, seq=128, batch=4),
    "mid100m": ModelConfig("mid100m", vocab=32768, d_model=768, n_layers=12,
                           n_heads=12, d_ff=3072, seq=256, batch=2),
}


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list — the Rust<->HLO parameter ABI."""
    specs: List[Tuple[str, Tuple[int, ...]]] = [
        ("embed.weight", (cfg.vocab, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layers.{i}"
        specs += [
            (f"{p}.ln1.scale", (cfg.d_model,)),
            (f"{p}.attn.wq", (cfg.d_model, cfg.d_model)),
            (f"{p}.attn.wk", (cfg.d_model, cfg.d_model)),
            (f"{p}.attn.wv", (cfg.d_model, cfg.d_model)),
            (f"{p}.attn.wo", (cfg.d_model, cfg.d_model)),
            (f"{p}.ln2.scale", (cfg.d_model,)),
            (f"{p}.mlp.w1", (cfg.d_model, cfg.d_ff)),
            (f"{p}.mlp.w2", (cfg.d_ff, cfg.d_model)),
        ]
    specs += [
        ("final_ln.scale", (cfg.d_model,)),
        ("head.weight", (cfg.d_model, cfg.vocab)),
    ]
    return specs


def init_params(cfg: ModelConfig, seed: int = 0) -> List[jax.Array]:
    """Initialize the flat parameter list (scaled-normal / ones for LN)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_specs(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("scale"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "embed.weight":
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
        else:
            fan_in = shape[0]
            params.append(jax.random.normal(sub, shape, jnp.float32)
                          * (fan_in ** -0.5))
    return params


def _rmsnorm(x: jax.Array, scale: jax.Array) -> jax.Array:
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _attention(x: jax.Array, wq, wk, wv, wo, cfg: ModelConfig) -> jax.Array:
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ wq).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = (x @ wk).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = (x @ wv).reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = q @ k.transpose(0, 1, 3, 2) * (hd ** -0.5)
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = (probs @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ wo


def _mlp(x: jax.Array, w1, w2) -> jax.Array:
    """FFN through the Pallas MXU-tiled matmul (L1 on the hot path)."""
    b, t, d = x.shape
    flat = x.reshape(b * t, d)
    h = matmul_tiled(flat, w1)
    h = jax.nn.gelu(h)
    return matmul_tiled(h, w2).reshape(b, t, d)


def forward(cfg: ModelConfig, params: List[jax.Array],
            tokens: jax.Array) -> jax.Array:
    """Logits for int32 tokens of shape (batch, seq)."""
    it = iter(params)
    embed = next(it)
    x = embed[tokens]
    for _ in range(cfg.n_layers):
        ln1, wq, wk, wv, wo, ln2, w1, w2 = (next(it) for _ in range(8))
        x = x + _attention(_rmsnorm(x, ln1), wq, wk, wv, wo, cfg)
        x = x + _mlp(_rmsnorm(x, ln2), w1, w2)
    final_ln = next(it)
    head = next(it)
    return _rmsnorm(x, final_ln) @ head


def loss_fn(cfg: ModelConfig, params: List[jax.Array], tokens: jax.Array,
            targets: jax.Array) -> jax.Array:
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def make_train_step(cfg: ModelConfig):
    """(params..., tokens, targets) -> (loss, grads...) — the per-device step.

    Gradients are returned unscaled; the coordinator averages them across
    devices via ReduceScatter (the FSDP data path under study).
    """
    def train_step(*args):
        params = list(args[:-2])
        tokens, targets = args[-2], args[-1]
        loss, grads = jax.value_and_grad(
            lambda ps: loss_fn(cfg, ps, tokens, targets))(params)
        return (loss, *grads)
    return train_step


def make_eval_loss(cfg: ModelConfig):
    def eval_loss(*args):
        params = list(args[:-2])
        tokens, targets = args[-2], args[-1]
        return (loss_fn(cfg, params, tokens, targets),)
    return eval_loss
