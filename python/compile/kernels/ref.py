"""Pure-jnp reference oracles for every Pallas kernel (L1).

These are the ground truth the pytest suite checks the Pallas kernels
against (and the hypothesis property sweeps). They are also lowered into
"reference" HLO artifacts so the Rust integration tests can compare the
kernel artifact against the oracle artifact end-to-end through PJRT.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Symmetric linear absmax block quantization (paper §6.3 / Dettmers 8-bit
# Adam). The *kernel-level* oracle uses the linear code (what the Pallas
# quant kernel implements); the Rust optimizer layers Dettmers' dynamic
# code on top for the second-moment state (linear codes zero out small v
# and diverge — see rust/src/optim/adam8bit.rs). The system property under
# study — quant blocks must not straddle shard boundaries — is independent
# of the code.
QMAX = 127.0


def blockwise_quant_ref(x: jax.Array, block: int):
    """Quantize 1-D f32 `x` (len divisible by `block`) to int8 + per-block scales.

    Returns (q i8[len], scale f32[len/block]) with q = round(x / scale * 127).
    """
    n = x.shape[0]
    xb = x.reshape(n // block, block)
    absmax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.where(absmax > 0, absmax, 1.0)
    q = jnp.clip(jnp.round(xb / scale[:, None] * QMAX), -QMAX, QMAX)
    return q.reshape(n).astype(jnp.int8), scale


def blockwise_dequant_ref(q: jax.Array, scale: jax.Array, block: int):
    """Inverse of blockwise_quant_ref: f32 reconstruction."""
    n = q.shape[0]
    qb = q.astype(jnp.float32).reshape(n // block, block)
    return (qb * scale[:, None] / QMAX).reshape(n)


def adamw_step_ref(p, g, m, v, t, *, lr, beta1, beta2, eps, wd):
    """One fused AdamW step over flat f32 arrays. Returns (p', m', v')."""
    m2 = beta1 * m + (1.0 - beta1) * g
    v2 = beta2 * v + (1.0 - beta2) * g * g
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    return p2, m2, v2


# Newton–Schulz quintic coefficients used by Muon (Jordan et al. 2024).
NS_COEFFS = (3.4445, -4.7750, 2.0315)
NS_STEPS = 5


def newton_schulz_ref(g: jax.Array, steps: int = NS_STEPS):
    """Muon's matrix-sign iteration: orthogonalize 2-D matrix `g`.

    Quintic Newton–Schulz: X <- a X + b (XX^T) X + c (XX^T)^2 X on the
    Frobenius-normalized matrix. f32 throughout (CPU substrate).
    """
    a, b, c = NS_COEFFS
    transposed = g.shape[0] > g.shape[1]
    x = g.T if transposed else g
    x = x / (jnp.linalg.norm(x) + 1e-7)
    for _ in range(steps):
        gram = x @ x.T
        x = a * x + (b * gram + c * (gram @ gram)) @ x
    return x.T if transposed else x


def matmul_ref(x: jax.Array, w: jax.Array):
    """Plain f32 matmul oracle for the tiled Pallas matmul."""
    return x @ w


def adam8bit_step_ref(p, g, m_q, m_scale, v_q, v_scale, t, *, lr, beta1,
                      beta2, eps, wd, block):
    """8-bit Adam step: dequantize states, AdamW update, requantize.

    All quant blocks live entirely in this shard — RaggedShard guarantees it.
    """
    m = blockwise_dequant_ref(m_q, m_scale, block)
    v = blockwise_dequant_ref(v_q, v_scale, block)
    v = jnp.maximum(v, 0.0)  # v is nonnegative; quant noise may break that
    p2, m2, v2 = adamw_step_ref(p, g, m, v, t, lr=lr, beta1=beta1,
                                beta2=beta2, eps=eps, wd=wd)
    m_q2, m_s2 = blockwise_quant_ref(m2, block)
    v_q2, v_s2 = blockwise_quant_ref(v2, block)
    return p2, m_q2, m_s2, v_q2, v_s2
