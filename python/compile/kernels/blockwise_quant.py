"""Block-wise INT8 quantization Pallas kernels (paper §6.3, 8-bit Adam).

TPU adaptation of the paper's CUDA block-quant path: one grid step = one
row of quant blocks resident in VMEM. The absmax reduction, scale compute,
and rounding all happen in-tile — a single HBM read and a single HBM write
per element, which is the roofline for this memory-bound kernel.

The kernel operates on a 2-D view ``(n_blocks, block)`` of the flat state
tensor: ``BlockSpec((ROWS, block))`` maps ROWS quant blocks per grid step
into VMEM. RaggedShard guarantees each quant block lives entirely on one
device, so the kernel never needs cross-device metadata (the paper's core
flexibility claim).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

QMAX = 127.0

# Rows of quant blocks per grid step. With block=1024 and f32 this is
# 64 KiB per tile operand — far under the ~16 MiB VMEM budget; chosen so
# the (8, 128)-lane VPU tiling is fully utilized.
_ROWS = 16


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...]                                  # (ROWS, block) in VMEM
    absmax = jnp.max(jnp.abs(x), axis=1)            # in-tile reduction
    scale = jnp.where(absmax > 0, absmax, 1.0)
    q = jnp.clip(jnp.round(x / scale[:, None] * QMAX), -QMAX, QMAX)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale


def _dequant_kernel(q_ref, s_ref, x_ref):
    q = q_ref[...].astype(jnp.float32)
    x_ref[...] = q * s_ref[...][:, None] / QMAX


def _grid_rows(n_blocks: int) -> int:
    return min(_ROWS, n_blocks)


@functools.partial(jax.jit, static_argnames=("block",))
def blockwise_quant(x: jax.Array, block: int):
    """Quantize flat f32 ``x`` (len % block == 0) to (int8 codes, f32 scales)."""
    n = x.shape[0]
    n_blocks = n // block
    rows = _grid_rows(n_blocks)
    assert n_blocks % rows == 0, (n_blocks, rows)
    xb = x.reshape(n_blocks, block)
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(n_blocks // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_blocks, block), jnp.int8),
            jax.ShapeDtypeStruct((n_blocks,), jnp.float32),
        ],
        interpret=True,
    )(xb)
    return q.reshape(n), s


@functools.partial(jax.jit, static_argnames=("block",))
def blockwise_dequant(q: jax.Array, scale: jax.Array, block: int):
    """Dequantize (int8 codes, f32 scales) back to flat f32."""
    n = q.shape[0]
    n_blocks = n // block
    rows = _grid_rows(n_blocks)
    assert n_blocks % rows == 0, (n_blocks, rows)
    qb = q.reshape(n_blocks, block)
    x = pl.pallas_call(
        _dequant_kernel,
        grid=(n_blocks // rows,),
        in_specs=[
            pl.BlockSpec((rows, block), lambda i: (i, 0)),
            pl.BlockSpec((rows,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, block), jnp.float32),
        interpret=True,
    )(qb, scale)
    return x.reshape(n)
