"""Fused AdamW update as a single Pallas kernel.

This is the TPU analogue of DBuffer's fused group-op (paper §5): instead of
four per-tensor kernel launches (m update, v update, bias correction, param
update) the whole optimizer step is one VMEM pass per tile — one read of
(p, g, m, v) and one write of (p', m', v'), the memory-bound roofline.

Hyper-parameters arrive as a runtime vector ``h = [t, lr, beta1, beta2,
eps, wd]`` so a single AOT artifact serves every run configuration (the
Rust runtime feeds the vector each step).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1-D tile: 64 Ki f32 elements = 256 KiB per operand in VMEM; 7 live operands
# => ~1.8 MiB, comfortably inside the ~16 MiB VMEM budget.
_TILE = 65536
HYPER_LEN = 6  # [t, lr, beta1, beta2, eps, wd]


def _adamw_kernel(h_ref, p_ref, g_ref, m_ref, v_ref, p_out, m_out, v_out):
    h = h_ref[...]
    t, lr, beta1, beta2, eps, wd = h[0], h[1], h[2], h[3], h[4], h[5]
    p = p_ref[...]
    g = g_ref[...]
    m = beta1 * m_ref[...] + (1.0 - beta1) * g
    v = beta2 * v_ref[...] + (1.0 - beta2) * g * g
    mhat = m / (1.0 - beta1**t)
    vhat = v / (1.0 - beta2**t)
    p_out[...] = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p)
    m_out[...] = m
    v_out[...] = v


@jax.jit
def fused_adamw(h, p, g, m, v):
    """One AdamW step over flat f32 arrays; returns (p', m', v').

    ``h`` is the f32 hyper vector ``[t, lr, beta1, beta2, eps, wd]``.
    """
    n = p.shape[0]
    tile = min(_TILE, n)
    assert n % tile == 0, (n, tile)
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    # h is broadcast to every grid step (index_map pins block 0).
    h_spec = pl.BlockSpec((HYPER_LEN,), lambda i: (0,))
    return pl.pallas_call(
        _adamw_kernel,
        grid=(n // tile,),
        in_specs=[h_spec, spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 3,
        interpret=True,
    )(h, p, g, m, v)
