"""MXU-tiled Pallas matmul with a custom VJP.

Used on the model's FFN hot path (L2 calls this, so it lowers into the
train-step HLO) and by the Newton-Schulz kernel. The backward pass is two
more tiled matmuls (dX = dY @ W^T, dW = X^T @ dY) — defining the VJP by
hand is what lets a Pallas primitive sit inside ``jax.grad``.

TPU adaptation: the CUDA tiling (threadblock tiles in shared memory,
software pipelining over K) becomes a 3-D Pallas grid (i, j, k) with
(TM, TK) x (TK, TN) VMEM tiles and an f32 accumulator initialized at k == 0
— BlockSpec index maps express the HBM<->VMEM schedule the CUDA kernel
expressed with blockIdx arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles. 128 matches the systolic array edge; TK=128 keeps each
# operand tile at 64 KiB f32 and the accumulator at 64 KiB.
_TM, _TN, _TK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
    o_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                          preferred_element_type=jnp.float32)


def _tile(n: int, t: int) -> int:
    """Largest divisor of n that is <= t (grid must divide exactly)."""
    t = min(t, n)
    while n % t:
        t -= 1
    return t


def _matmul_pallas(x: jax.Array, w: jax.Array) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, (x.shape, w.shape)
    tm, tn, tk = _tile(m, _TM), _tile(n, _TN), _tile(k, _TK)
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // tm, n // tn, k // tk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul_tiled(x: jax.Array, w: jax.Array) -> jax.Array:
    """f32 matmul ``x @ w`` through the MXU-tiled Pallas kernel."""
    return _matmul_pallas(x, w)


def _fwd(x, w):
    return _matmul_pallas(x, w), (x, w)


def _bwd(res, dy):
    x, w = res
    dx = _matmul_pallas(dy, w.T)
    dw = _matmul_pallas(x.T, dy)
    return dx, dw


matmul_tiled.defvjp(_fwd, _bwd)
