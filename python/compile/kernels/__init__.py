"""L1 Pallas kernels for the veScale-FSDP reproduction.

All kernels run under ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call that the CPU PJRT plugin cannot execute. Structure (BlockSpecs,
grids, VMEM tiling) is written for the TPU MXU/VMEM model; see
DESIGN.md §Hardware-Adaptation.
"""
from .blockwise_quant import blockwise_quant, blockwise_dequant
from .fused_adamw import fused_adamw
from .newton_schulz import newton_schulz
from .matmul import matmul_tiled

__all__ = [
    "blockwise_quant",
    "blockwise_dequant",
    "fused_adamw",
    "newton_schulz",
    "matmul_tiled",
]
