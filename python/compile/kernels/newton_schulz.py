"""Newton–Schulz orthogonalization (Muon) as MXU-tiled Pallas matmuls.

Muon's matrix-sign preconditioner is matmul-dominated. The CUDA version
tiles the GEMMs over threadblocks + shared memory; the TPU rethink tiles
them for the 128x128 MXU systolic array with a K-loop expressed through the
Pallas grid (HBM->VMEM schedule via BlockSpec index maps), accumulating in
an f32 VMEM scratch tile.

The 5-step quintic iteration X <- aX + (b*G + c*G^2)X with G = XX^T runs at
the JAX level, each matmul dispatching into the tiled kernel, so the whole
iteration lowers into one HLO module for the Rust runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul_tiled
from .ref import NS_COEFFS, NS_STEPS


def newton_schulz(g: jax.Array, steps: int = NS_STEPS) -> jax.Array:
    """Orthogonalize 2-D f32 ``g`` via quintic Newton-Schulz (Muon)."""
    a, b, c = NS_COEFFS
    transposed = g.shape[0] > g.shape[1]
    x = g.T if transposed else g
    x = x / (jnp.linalg.norm(x) + 1e-7)
    for _ in range(steps):
        gram = matmul_tiled(x, x.T)                 # (m, m) on the MXU
        gram2 = matmul_tiled(gram, gram)
        x = a * x + matmul_tiled(b * gram + c * gram2, x)
    return x.T if transposed else x
