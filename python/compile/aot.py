"""AOT lowering: every L2/L1 entry point -> artifacts/*.hlo.txt + manifest.

Interchange is HLO *text*, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` 0.1.6 Rust crate links) rejects; the text parser
reassigns ids and round-trips cleanly.

Run once at build time (``make artifacts``); Python never executes on the
training path. The manifest records every artifact's I/O signature and the
flat parameter ABI so the Rust runtime and the HLO agree by construction.

Usage: python -m compile.aot --out-dir ../artifacts [--configs tiny,small]
"""
from __future__ import annotations

import argparse
import hashlib
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import (blockwise_dequant, blockwise_quant, fused_adamw,
                      newton_schulz)
from .kernels.fused_adamw import HYPER_LEN

# Flat-shard optimizer chunk (elements). Rust pads shard tails to this.
CHUNK = 65536
# Quantization block for 8-bit Adam state (elements of the flat shard).
QBLOCK = 1024


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _sig(avals):
    return [{"shape": list(a.shape), "dtype": a.dtype.name} for a in avals]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def adam8bit_chunk(h, p, g, m_q, m_s, v_q, v_s):
    """8-bit Adam step on one flat CHUNK. Codes travel as f32 carriers
    (integer values in [-127, 127]); Rust stores them as real int8 — the
    memory accounting lives in L3, the math lives here."""
    m = blockwise_dequant(m_q.astype(jnp.int8), m_s, QBLOCK)
    v = blockwise_dequant(v_q.astype(jnp.int8), v_s, QBLOCK)
    v = jnp.maximum(v, 0.0)
    p2, m2, v2 = fused_adamw(h, p, g, m, v)
    m_q2, m_s2 = blockwise_quant(m2, QBLOCK)
    v_q2, v_s2 = blockwise_quant(v2, QBLOCK)
    return (p2, m_q2.astype(jnp.float32), m_s2,
            v_q2.astype(jnp.float32), v_s2)


def quant_chunk(x):
    q, s = blockwise_quant(x, QBLOCK)
    return q.astype(jnp.float32), s


def dequant_chunk(q, s):
    return (blockwise_dequant(q.astype(jnp.int8), s, QBLOCK),)


def adamw_entry(h, p, g, m, v):
    return fused_adamw(h, p, g, m, v)


def ns_entry(g):
    return (newton_schulz(g),)


def _lower(fn, example_args):
    return jax.jit(fn).lower(*example_args)


def build(out_dir: str, config_names):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "version": 1,
        "chunk": CHUNK,
        "qblock": QBLOCK,
        "hyper_len": HYPER_LEN,
        "configs": {},
        "artifacts": [],
    }

    def emit(name: str, fn, example_args):
        t0 = time.time()
        lowered = _lower(fn, example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        manifest["artifacts"].append({
            "name": name,
            "file": fname,
            "inputs": _sig(example_args),
            "outputs": _sig(list(out_avals)),
            "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
        })
        print(f"  emitted {name}: {len(text)/1e6:.2f} MB HLO "
              f"({time.time()-t0:.1f}s)")

    # ---- optimizer / kernel chunk artifacts (config-independent) ----
    hyper = _spec((HYPER_LEN,))
    flat = _spec((CHUNK,))
    nsc = _spec((CHUNK // QBLOCK,))
    emit("adamw_chunk", adamw_entry, (hyper, flat, flat, flat, flat))
    emit("adam8bit_chunk", adam8bit_chunk,
         (hyper, flat, flat, flat, nsc, flat, nsc))
    emit("quant_chunk", quant_chunk, (flat,))
    emit("dequant_chunk", dequant_chunk, (flat, nsc))

    # ---- per-config model + Muon artifacts ----
    for cname in config_names:
        cfg = model.CONFIGS[cname]
        specs = model.param_specs(cfg)
        manifest["configs"][cname] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq": cfg.seq, "batch": cfg.batch,
            "params": [{"name": n, "shape": list(s)} for n, s in specs],
        }
        p_args = [_spec(s) for _, s in specs]
        tok = _spec((cfg.batch, cfg.seq), jnp.int32)

        def train_entry(*args, _cfg=cfg):
            out = model.make_train_step(_cfg)(*args)
            return (out[0].reshape(1), *out[1:])

        def eval_entry(*args, _cfg=cfg):
            (loss,) = model.make_eval_loss(_cfg)(*args)
            return (loss.reshape(1),)

        emit(f"train_step_{cname}", train_entry, (*p_args, tok, tok))
        emit(f"eval_loss_{cname}", eval_entry, (*p_args, tok, tok))

        # Newton-Schulz per distinct 2-D hidden-matrix shape (Muon operates
        # on hidden layers only, not embeddings/head — Jordan et al.).
        ns_shapes = sorted({s for n, s in specs
                            if len(s) == 2 and "embed" not in n
                            and "head" not in n})
        for shape in ns_shapes:
            emit(f"newton_schulz_{shape[0]}x{shape[1]}", ns_entry,
                 (_spec(shape),))

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['artifacts'])} artifacts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--configs", default="tiny,small")
    args = ap.parse_args()
    build(args.out_dir, [c for c in args.configs.split(",") if c])


if __name__ == "__main__":
    main()
