"""Golden-vector parity: the Pallas block-wise quant kernel vs the shared
JSON fixtures in ``rust/tests/fixtures/blockwise_quant_golden.json``.

The same file is asserted against both Rust implementations
(``optim/adam8bit.rs`` and ``quant/``) by ``rust/tests/quant_parity.rs``,
tying all three to one source of truth: absmax scale with the 1.0
zero-block fallback, round half to even (``jnp.round``), clip to ±127.
"""
import json
import os

import jax.numpy as jnp
import numpy as np

from compile.kernels import blockwise_dequant, blockwise_quant

_FIXTURE = os.path.join(
    os.path.dirname(os.path.abspath(__file__)), os.pardir, os.pardir,
    "rust", "tests", "fixtures", "blockwise_quant_golden.json")


def _cases():
    with open(_FIXTURE) as f:
        return json.load(f)["cases"]


def test_golden_codes_and_scales():
    for case in _cases():
        x = jnp.asarray(np.asarray(case["x"], np.float32))
        q, s = blockwise_quant(x, case["block"])
        np.testing.assert_array_equal(
            np.asarray(q), np.asarray(case["q"], np.int8), err_msg=case["name"])
        np.testing.assert_array_equal(
            np.asarray(s), np.asarray(case["scales"], np.float32),
            err_msg=case["name"])


def test_golden_dequant_is_q_scale_over_127():
    for case in _cases():
        block = case["block"]
        q = jnp.asarray(np.asarray(case["q"], np.int8))
        s = jnp.asarray(np.asarray(case["scales"], np.float32))
        x = blockwise_dequant(q, s, block)
        expect = (np.asarray(case["q"], np.float32).reshape(-1, block)
                  * np.asarray(case["scales"], np.float32)[:, None]
                  / 127.0).reshape(-1)
        np.testing.assert_array_equal(np.asarray(x), expect,
                                      err_msg=case["name"])
