"""AOT pipeline: HLO-text interchange format and manifest integrity."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels.fused_adamw import HYPER_LEN


class TestHloText:
    def test_simple_fn_lowers_to_hlo_text(self):
        f = lambda x, y: (jnp.matmul(x, y) + 2.0,)
        spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
        text = aot.to_hlo_text(jax.jit(f).lower(spec, spec))
        assert "HloModule" in text
        assert "ENTRY" in text

    def test_pallas_kernel_lowers_to_plain_hlo(self):
        # interpret=True must not leave custom-calls the CPU client can't run
        from compile.kernels import fused_adamw
        h = jax.ShapeDtypeStruct((HYPER_LEN,), jnp.float32)
        v = jax.ShapeDtypeStruct((2048,), jnp.float32)
        text = aot.to_hlo_text(jax.jit(fused_adamw).lower(h, v, v, v, v))
        assert "HloModule" in text
        assert "mosaic" not in text.lower()

    def test_train_step_micro_lowers(self):
        cfg = model.ModelConfig("micro", vocab=64, d_model=32, n_layers=1,
                                n_heads=2, d_ff=64, seq=16, batch=2)
        specs = [jax.ShapeDtypeStruct(s, jnp.float32)
                 for _, s in model.param_specs(cfg)]
        tok = jax.ShapeDtypeStruct((cfg.batch, cfg.seq), jnp.int32)

        def entry(*args):
            out = model.make_train_step(cfg)(*args)
            return (out[0].reshape(1), *out[1:])

        text = aot.to_hlo_text(jax.jit(entry).lower(*specs, tok, tok))
        assert "HloModule" in text


class TestManifest:
    """Validates the manifest produced by `make artifacts` if present."""
    MANIFEST = os.path.join(os.path.dirname(__file__), "..", "..",
                            "artifacts", "manifest.json")

    @pytest.fixture
    def manifest(self):
        if not os.path.exists(self.MANIFEST):
            pytest.skip("artifacts not built yet (run `make artifacts`)")
        with open(self.MANIFEST) as f:
            return json.load(f)

    def test_artifact_files_exist(self, manifest):
        d = os.path.dirname(self.MANIFEST)
        for art in manifest["artifacts"]:
            assert os.path.exists(os.path.join(d, art["file"])), art["name"]

    def test_chunk_artifacts_present(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for required in ("adamw_chunk", "adam8bit_chunk", "quant_chunk",
                         "dequant_chunk"):
            assert required in names

    def test_configs_have_train_and_eval(self, manifest):
        names = {a["name"] for a in manifest["artifacts"]}
        for cname in manifest["configs"]:
            assert f"train_step_{cname}" in names
            assert f"eval_loss_{cname}" in names

    def test_param_abi_matches_model(self, manifest):
        for cname, c in manifest["configs"].items():
            cfg = model.CONFIGS[cname]
            specs = model.param_specs(cfg)
            assert len(specs) == len(c["params"])
            for (name, shape), rec in zip(specs, c["params"]):
                assert rec["name"] == name
                assert tuple(rec["shape"]) == tuple(shape)

    def test_train_step_signature(self, manifest):
        for cname, c in manifest["configs"].items():
            art = next(a for a in manifest["artifacts"]
                       if a["name"] == f"train_step_{cname}")
            n_params = len(c["params"])
            assert len(art["inputs"]) == n_params + 2
            assert len(art["outputs"]) == n_params + 1  # loss + grads
            assert art["outputs"][0]["shape"] == [1]

    def test_adam8bit_signature(self, manifest):
        art = next(a for a in manifest["artifacts"]
                   if a["name"] == "adam8bit_chunk")
        chunk, qb = manifest["chunk"], manifest["qblock"]
        shapes = [tuple(i["shape"]) for i in art["inputs"]]
        assert shapes == [(manifest["hyper_len"],), (chunk,), (chunk,),
                          (chunk,), (chunk // qb,), (chunk,), (chunk // qb,)]
