"""L2 model: shapes, gradients, trainability."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


MICRO = model.ModelConfig("micro", vocab=64, d_model=32, n_layers=2,
                          n_heads=2, d_ff=64, seq=16, batch=2)


def _batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (cfg.batch, cfg.seq + 1), 0, cfg.vocab)
    return toks[:, :-1], toks[:, 1:]


class TestParamABI:
    def test_specs_match_init(self):
        for cfg in (MICRO, model.CONFIGS["tiny"]):
            specs = model.param_specs(cfg)
            params = model.init_params(cfg)
            assert len(specs) == len(params)
            for (name, shape), p in zip(specs, params):
                assert tuple(shape) == p.shape, name

    def test_param_count_tiny(self):
        cfg = model.CONFIGS["tiny"]
        n = sum(int(np.prod(s)) for _, s in model.param_specs(cfg))
        # 2 * vocab * d + L * (4d^2 + 2*d*dff + 2d) + d
        expected = (2 * cfg.vocab * cfg.d_model
                    + cfg.n_layers * (4 * cfg.d_model**2
                                      + 2 * cfg.d_model * cfg.d_ff
                                      + 2 * cfg.d_model)
                    + cfg.d_model)
        assert n == expected

    def test_spec_order_deterministic(self):
        a = model.param_specs(model.CONFIGS["tiny"])
        b = model.param_specs(model.CONFIGS["tiny"])
        assert a == b


class TestForward:
    def test_logits_shape_and_finite(self):
        params = model.init_params(MICRO)
        tokens, _ = _batch(MICRO)
        logits = model.forward(MICRO, params, tokens)
        assert logits.shape == (MICRO.batch, MICRO.seq, MICRO.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_initial_loss_near_uniform(self):
        params = model.init_params(MICRO)
        tokens, targets = _batch(MICRO)
        loss = model.loss_fn(MICRO, params, tokens, targets)
        assert abs(float(loss) - np.log(MICRO.vocab)) < 0.5

    def test_causality(self):
        # changing a future token must not change past logits
        params = model.init_params(MICRO)
        tokens, _ = _batch(MICRO)
        l1 = model.forward(MICRO, params, tokens)
        tokens2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % MICRO.vocab)
        l2 = model.forward(MICRO, params, tokens2)
        np.testing.assert_allclose(np.asarray(l1[:, :-1]),
                                   np.asarray(l2[:, :-1]), atol=1e-5)


class TestTrainStep:
    def test_grads_match_pure_jnp(self):
        # the pallas custom-VJP path must agree with an all-jnp model
        params = model.init_params(MICRO)
        tokens, targets = _batch(MICRO)
        step = model.make_train_step(MICRO)
        out = step(*params, tokens, targets)
        loss, grads = out[0], out[1:]

        import compile.model as m
        orig = m.matmul_tiled
        m.matmul_tiled = lambda a, b: a @ b
        try:
            out_ref = model.make_train_step(MICRO)(*params, tokens, targets)
        finally:
            m.matmul_tiled = orig
        np.testing.assert_allclose(float(loss), float(out_ref[0]), rtol=1e-5)
        for g, gr in zip(grads, out_ref[1:]):
            np.testing.assert_allclose(np.asarray(g), np.asarray(gr),
                                       atol=2e-4)

    def test_loss_decreases_under_sgd(self):
        params = model.init_params(MICRO)
        tokens, targets = _batch(MICRO)
        step = jax.jit(model.make_train_step(MICRO))
        losses = []
        for _ in range(20):
            out = step(*params, tokens, targets)
            losses.append(float(out[0]))
            params = [p - 0.5 * g for p, g in zip(params, out[1:])]
        assert losses[-1] < losses[0] - 0.5, losses

    def test_grad_count_matches_params(self):
        params = model.init_params(MICRO)
        tokens, targets = _batch(MICRO)
        out = model.make_train_step(MICRO)(*params, tokens, targets)
        assert len(out) == 1 + len(params)
