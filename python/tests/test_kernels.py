"""L1 Pallas kernels vs pure-jnp oracles — the core correctness signal."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (blockwise_dequant, blockwise_quant, fused_adamw,
                             matmul_tiled, newton_schulz)
from compile.kernels import ref


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape,
                             jnp.float32) * scale


# ---------------------------------------------------------------- quant ---

class TestBlockwiseQuant:
    def test_matches_ref_codes_and_scales(self):
        x = _rand(0, (65536,))
        q, s = blockwise_quant(x, 1024)
        qr, sr = ref.blockwise_quant_ref(x, 1024)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr))

    def test_roundtrip_error_bounded_per_block(self):
        x = _rand(1, (16384,), scale=3.0)
        q, s = blockwise_quant(x, 1024)
        xd = blockwise_dequant(q, s, 1024)
        err = jnp.abs(xd - x).reshape(16, 1024).max(axis=1)
        # one quantization step = scale/127; rounding error <= half step + ulp
        bound = s / 127.0 * 0.5 + 1e-6
        assert bool(jnp.all(err <= bound))

    def test_zero_block_is_exact(self):
        x = jnp.zeros((2048,), jnp.float32)
        q, s = blockwise_quant(x, 1024)
        assert bool(jnp.all(q == 0))
        np.testing.assert_array_equal(np.asarray(s), np.ones(2))
        np.testing.assert_array_equal(
            np.asarray(blockwise_dequant(q, s, 1024)), np.zeros(2048))

    def test_absmax_element_is_exact(self):
        # the element attaining absmax quantizes to +-127 -> exact recovery
        x = _rand(2, (4096,))
        q, s = blockwise_quant(x, 1024)
        xb = np.asarray(x).reshape(4, 1024)
        xd = np.asarray(blockwise_dequant(q, s, 1024)).reshape(4, 1024)
        for b in range(4):
            i = np.argmax(np.abs(xb[b]))
            np.testing.assert_allclose(xd[b, i], xb[b, i], rtol=1e-6)

    def test_block_independence(self):
        # mutating one block must not change other blocks' codes (the
        # property RaggedShard relies on: blocks quantize independently)
        x = _rand(3, (8192,))
        q1, s1 = blockwise_quant(x, 1024)
        x2 = x.at[:1024].mul(100.0)
        q2, s2 = blockwise_quant(x2, 1024)
        np.testing.assert_array_equal(np.asarray(q1)[1024:],
                                      np.asarray(q2)[1024:])
        np.testing.assert_allclose(np.asarray(s1)[1:], np.asarray(s2)[1:])

    @settings(max_examples=20, deadline=None)
    @given(nb=st.integers(1, 8), block=st.sampled_from([128, 256, 1024]),
           seed=st.integers(0, 2**31 - 1), scale=st.floats(1e-3, 1e3))
    def test_hypothesis_roundtrip(self, nb, block, seed, scale):
        x = _rand(seed, (nb * block,), scale=scale)
        q, s = blockwise_quant(x, block)
        qr, sr = ref.blockwise_quant_ref(x, block)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
        np.testing.assert_allclose(np.asarray(s), np.asarray(sr))


# ---------------------------------------------------------------- adamw ---

class TestFusedAdamw:
    HYPER = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.01)

    def _h(self, t):
        hp = self.HYPER
        return jnp.array([t, hp["lr"], hp["beta1"], hp["beta2"], hp["eps"],
                          hp["wd"]], jnp.float32)

    def test_matches_ref(self):
        n = 65536
        p, g = _rand(0, (n,)), _rand(1, (n,))
        m, v = _rand(2, (n,), 0.1), jnp.abs(_rand(3, (n,), 0.01))
        p2, m2, v2 = fused_adamw(self._h(5.0), p, g, m, v)
        pr, mr, vr = ref.adamw_step_ref(p, g, m, v, 5.0, **self.HYPER)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(m2), np.asarray(mr), atol=1e-6)
        np.testing.assert_allclose(np.asarray(v2), np.asarray(vr), atol=1e-6)

    def test_multi_tile_grid(self):
        n = 65536 * 2  # forces a 2-step grid
        p, g = _rand(4, (n,)), _rand(5, (n,))
        m, v = jnp.zeros(n), jnp.zeros(n)
        p2, m2, v2 = fused_adamw(self._h(1.0), p, g, m, v)
        pr, mr, vr = ref.adamw_step_ref(p, g, m, v, 1.0, **self.HYPER)
        np.testing.assert_allclose(np.asarray(p2), np.asarray(pr), atol=1e-6)

    def test_zero_grad_pure_decay(self):
        n = 1024
        p = _rand(6, (n,))
        z = jnp.zeros(n)
        p2, m2, v2 = fused_adamw(self._h(1.0), p, z, z, z)
        np.testing.assert_allclose(np.asarray(p2),
                                   np.asarray(p * (1 - 1e-3 * 0.01)),
                                   rtol=1e-6)
        assert float(jnp.abs(m2).max()) == 0.0

    @settings(max_examples=15, deadline=None)
    @given(t=st.integers(1, 10000), lr=st.floats(1e-5, 1e-1),
           b1=st.floats(0.0, 0.99), b2=st.floats(0.9, 0.9999),
           wd=st.floats(0.0, 0.3), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_hyper_sweep(self, t, lr, b1, b2, wd, seed):
        n = 2048
        p, g = _rand(seed, (n,)), _rand(seed + 1, (n,))
        m, v = _rand(seed + 2, (n,), 0.1), jnp.abs(_rand(seed + 3, (n,), 0.01))
        h = jnp.array([t, lr, b1, b2, 1e-8, wd], jnp.float32)
        p2, _, _ = fused_adamw(h, p, g, m, v)
        pr, _, _ = ref.adamw_step_ref(p, g, m, v, float(t), lr=lr, beta1=b1,
                                      beta2=b2, eps=1e-8, wd=wd)
        # kernel and oracle differ only by f32 op ordering; near-singular
        # bias corrections (beta^t ~ 1e-5 deltas) amplify that noise, so
        # bound at 1e-3 relative — still catches any real math error
        np.testing.assert_allclose(np.asarray(p2), np.asarray(pr),
                                   rtol=1e-3, atol=1e-4)


# --------------------------------------------------------------- matmul ---

class TestMatmulTiled:
    def test_matches_ref(self):
        x, w = _rand(0, (128, 512)), _rand(1, (512, 256))
        np.testing.assert_allclose(np.asarray(matmul_tiled(x, w)),
                                   np.asarray(x @ w), atol=1e-3)

    def test_non_multiple_of_128(self):
        # _tile falls back to exact divisors for awkward shapes
        x, w = _rand(2, (96, 80)), _rand(3, (80, 112))
        np.testing.assert_allclose(np.asarray(matmul_tiled(x, w)),
                                   np.asarray(x @ w), atol=1e-3)

    def test_custom_vjp_matches_jnp_grad(self):
        x, w = _rand(4, (64, 128)), _rand(5, (128, 64))
        f_pallas = lambda x, w: jnp.sum(jnp.sin(matmul_tiled(x, w)))
        f_ref = lambda x, w: jnp.sum(jnp.sin(x @ w))
        gx, gw = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        rx, rw = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(np.asarray(gx), np.asarray(rx), atol=1e-4)
        np.testing.assert_allclose(np.asarray(gw), np.asarray(rw), atol=1e-4)

    @settings(max_examples=10, deadline=None)
    @given(m=st.sampled_from([32, 128, 160]), k=st.sampled_from([64, 128]),
           n=st.sampled_from([32, 256]), seed=st.integers(0, 1000))
    def test_hypothesis_shapes(self, m, k, n, seed):
        x, w = _rand(seed, (m, k)), _rand(seed + 1, (k, n))
        np.testing.assert_allclose(np.asarray(matmul_tiled(x, w)),
                                   np.asarray(x @ w), atol=1e-3)


# -------------------------------------------------------- newton-schulz ---

class TestNewtonSchulz:
    def test_matches_ref(self):
        g = _rand(0, (128, 512))
        np.testing.assert_allclose(np.asarray(newton_schulz(g)),
                                   np.asarray(ref.newton_schulz_ref(g)),
                                   atol=1e-4)

    def test_tall_matrix_transpose_path(self):
        g = _rand(1, (512, 128))
        np.testing.assert_allclose(np.asarray(newton_schulz(g)),
                                   np.asarray(ref.newton_schulz_ref(g)),
                                   atol=1e-4)

    def test_approximate_orthogonalization(self):
        # after 5 quintic steps singular values concentrate near 1
        g = _rand(2, (128, 256))
        sv = jnp.linalg.svd(newton_schulz(g), compute_uv=False)
        assert float(sv.min()) > 0.3
        assert float(sv.max()) < 1.6

    def test_sign_preservation_square(self):
        # NS approximates the matrix sign: UV^T from the SVD of g
        g = _rand(3, (128, 128))
        u, _, vt = jnp.linalg.svd(g, full_matrices=False)
        target = u @ vt
        got = newton_schulz(g)
        # loose tolerance: 5 steps is an approximation
        cos = jnp.sum(got * target) / (jnp.linalg.norm(got) *
                                       jnp.linalg.norm(target))
        assert float(cos) > 0.98


# ------------------------------------------------------------ adam8bit ---

class TestAdam8bitRef:
    def test_state_memory_is_8bit_semantics(self):
        # quantize -> step -> requantize keeps params close to fp32 Adam
        n, block = 16384, 1024
        p, g = _rand(0, (n,)), _rand(1, (n,))
        m, v = _rand(2, (n,), 0.1), jnp.abs(_rand(3, (n,), 0.01))
        mq, ms = ref.blockwise_quant_ref(m, block)
        vq, vs = ref.blockwise_quant_ref(v, block)
        hp = dict(lr=1e-3, beta1=0.9, beta2=0.999, eps=1e-8, wd=0.0)
        p8, *_ = ref.adam8bit_step_ref(p, g, mq, ms, vq, vs, 5.0, block=block,
                                       **hp)
        p32, _, _ = ref.adamw_step_ref(p, g, m, v, 5.0, **hp)
        # 8-bit state noise stays within a few tens of lr of fp32 (the v
        # quantization error is amplified by the rsqrt for tiny v)
        assert float(jnp.max(jnp.abs(p8 - p32))) < 5e-2
        assert float(jnp.mean(jnp.abs(p8 - p32))) < 1e-4
